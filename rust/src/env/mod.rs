//! Environment simulators with OpenAI-gym semantics (paper §II-A).
//!
//! The paper evaluates on gym benchmarks (LunarLander-v2 etc.). Python
//! cannot be on the request path, so the environments are pure-Rust
//! re-implementations of the classic-control dynamics, plus a 2-D
//! thruster lander (`lunar_lander`, our LunarLander-v2 substitute) and a
//! synthetic `RandomMDP` whose per-step cost is tunable — used by the
//! throughput benches to sweep the actor/learner balance (Fig 12).

pub mod acrobot;
pub mod cartpole;
pub mod lunar_lander;
pub mod mountain_car;
pub mod pendulum;
pub mod random_mdp;

pub use acrobot::Acrobot;
pub use cartpole::CartPole;
pub use lunar_lander::LunarLanderLite;
pub use mountain_car::{MountainCar, MountainCarContinuous};
pub use pendulum::Pendulum;
pub use random_mdp::RandomMdp;

use crate::util::rng::Rng;

/// Action space of an environment.
#[derive(Clone, Debug, PartialEq)]
pub enum ActionSpace {
    /// `n` discrete actions, encoded as `[index as f32]`.
    Discrete(usize),
    /// Box action in `[low, high]^dim`.
    Continuous { dim: usize, low: f32, high: f32 },
}

impl ActionSpace {
    /// Width of the flat action vector stored in the replay buffer.
    pub fn flat_dim(&self) -> usize {
        match self {
            ActionSpace::Discrete(_) => 1,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    pub fn is_discrete(&self) -> bool {
        matches!(self, ActionSpace::Discrete(_))
    }
}

/// Static description of an environment.
#[derive(Clone, Debug)]
pub struct EnvSpec {
    pub name: &'static str,
    pub obs_dim: usize,
    pub action_space: ActionSpace,
    /// Episode truncation horizon (gym `TimeLimit`).
    pub max_episode_steps: usize,
    /// Reward at which the task counts as solved (for convergence tests).
    pub solved_reward: f32,
}

/// Result of one `step`.
#[derive(Clone, Debug)]
pub struct Step {
    pub obs: Vec<f32>,
    pub reward: f32,
    /// Terminal state reached (environment semantics).
    pub done: bool,
    /// Horizon hit (truncation — not a true terminal; the learner must
    /// still bootstrap).
    pub truncated: bool,
}

/// Gym-style environment: `reset` + `step` (paper §II-A API).
pub trait Env: Send {
    fn spec(&self) -> &EnvSpec;

    /// Sample an initial state from μ and return the first observation.
    fn reset(&mut self, rng: &mut Rng) -> Vec<f32>;

    /// Advance one step. `action` is the flat encoding described by
    /// [`ActionSpec::flat_dim`]. Does NOT auto-reset; the actor loop
    /// calls `reset` when `done || truncated`.
    fn step(&mut self, action: &[f32], rng: &mut Rng) -> Step;
}

/// Instantiate an environment by name.
///
/// Names mirror their gym counterparts where one exists.
pub fn make_env(name: &str) -> Option<Box<dyn Env>> {
    Some(match name {
        "CartPole-v1" | "cartpole" => Box::new(CartPole::new()),
        "Pendulum-v1" | "pendulum" => Box::new(Pendulum::new()),
        "MountainCar-v0" | "mountain_car" => Box::new(MountainCar::new()),
        "MountainCarContinuous-v0" | "mountain_car_continuous" => {
            Box::new(MountainCarContinuous::new())
        }
        "Acrobot-v1" | "acrobot" => Box::new(Acrobot::new()),
        "LunarLanderLite-v0" | "lunar_lander" => Box::new(LunarLanderLite::new()),
        "RandomMDP-v0" | "random_mdp" => Box::new(RandomMdp::new(16, 4, 0)),
        _ => return None,
    })
}

/// All registered environment names (docs, CLI help, tests).
pub const ENV_NAMES: &[&str] = &[
    "CartPole-v1",
    "Pendulum-v1",
    "MountainCar-v0",
    "MountainCarContinuous-v0",
    "Acrobot-v1",
    "LunarLanderLite-v0",
    "RandomMDP-v0",
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Generic conformance suite every environment must pass.
    fn conformance(mut env: Box<dyn Env>) {
        let name = env.spec().name;
        let spec = env.spec().clone();
        let mut rng = Rng::new(42);
        let obs = env.reset(&mut rng);
        assert_eq!(obs.len(), spec.obs_dim, "{name}: obs dim");
        assert!(obs.iter().all(|v| v.is_finite()), "{name}: finite obs");

        let action = match &spec.action_space {
            ActionSpace::Discrete(_) => vec![0.0],
            ActionSpace::Continuous { dim, low, high } => vec![(low + high) / 2.0; *dim],
        };
        let mut steps = 0usize;
        let mut episodes = 0usize;
        let mut total_reward = 0.0f32;
        let mut obs = obs;
        while steps < 3 * spec.max_episode_steps && episodes < 5 {
            let s = env.step(&action, &mut rng);
            assert_eq!(s.obs.len(), spec.obs_dim, "{name}");
            assert!(s.obs.iter().all(|v| v.is_finite()), "{name}: finite step obs");
            assert!(s.reward.is_finite(), "{name}: finite reward");
            total_reward += s.reward;
            steps += 1;
            if s.done || s.truncated {
                episodes += 1;
                obs = env.reset(&mut rng);
            } else {
                obs = s.obs;
            }
        }
        let _ = (obs, total_reward);
        assert!(episodes >= 1, "{name}: never terminated in {steps} steps");
    }

    #[test]
    fn all_envs_conform() {
        for name in ENV_NAMES {
            conformance(make_env(name).unwrap());
        }
    }

    #[test]
    fn unknown_env_is_none() {
        assert!(make_env("Atari-Breakout").is_none());
    }

    #[test]
    fn determinism_given_seed() {
        for name in ENV_NAMES {
            let run = |seed: u64| {
                let mut env = make_env(name).unwrap();
                let mut rng = Rng::new(seed);
                let mut trace = Vec::new();
                let mut obs = env.reset(&mut rng);
                trace.extend(obs.iter().copied());
                let act = match &env.spec().action_space {
                    ActionSpace::Discrete(n) => vec![(n - 1) as f32],
                    ActionSpace::Continuous { dim, high, .. } => vec![*high; *dim],
                };
                for _ in 0..50 {
                    let s = env.step(&act, &mut rng);
                    trace.push(s.reward);
                    if s.done || s.truncated {
                        obs = env.reset(&mut rng);
                        trace.extend(obs.iter().copied());
                    }
                }
                trace
            };
            assert_eq!(run(7), run(7), "{name} not deterministic");
            // And different seeds give different traces for stochastic envs.
        }
    }
}
