//! CartPole-v1: balance a pole on a cart (Barto, Sutton & Anderson 1983),
//! dynamics and constants identical to `gym.envs.classic_control.CartPoleEnv`.

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;

const GRAVITY: f32 = 9.8;
const MASS_CART: f32 = 1.0;
const MASS_POLE: f32 = 0.1;
const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
const LENGTH: f32 = 0.5; // half pole length
const POLE_MASS_LENGTH: f32 = MASS_POLE * LENGTH;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02; // seconds per step
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_LIMIT: f32 = 2.4;

pub struct CartPole {
    spec: EnvSpec,
    state: [f32; 4], // x, x_dot, theta, theta_dot
    steps: usize,
}

impl CartPole {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "CartPole-v1",
                obs_dim: 4,
                action_space: ActionSpace::Discrete(2),
                max_episode_steps: 500,
                solved_reward: 475.0,
            },
            state: [0.0; 4],
            steps: 0,
        }
    }
}

impl Default for CartPole {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for CartPole {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        for v in self.state.iter_mut() {
            *v = rng.range_f32(-0.05, 0.05);
        }
        self.steps = 0;
        self.state.to_vec()
    }

    fn step(&mut self, action: &[f32], _rng: &mut Rng) -> Step {
        let force = if action[0] >= 0.5 { FORCE_MAG } else { -FORCE_MAG };
        let [x, x_dot, theta, theta_dot] = self.state;
        let (sin_t, cos_t) = theta.sin_cos();
        let temp = (force + POLE_MASS_LENGTH * theta_dot * theta_dot * sin_t) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos_t / TOTAL_MASS;
        // Euler integration, gym order.
        self.state = [
            x + TAU * x_dot,
            x_dot + TAU * x_acc,
            theta + TAU * theta_dot,
            theta_dot + TAU * theta_acc,
        ];
        self.steps += 1;
        let done = self.state[0].abs() > X_LIMIT || self.state[2].abs() > THETA_LIMIT;
        let truncated = !done && self.steps >= self.spec.max_episode_steps;
        Step {
            obs: self.state.to_vec(),
            reward: 1.0,
            done,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pole_falls_under_constant_push() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        let mut steps = 0;
        loop {
            let s = env.step(&[1.0], &mut rng);
            steps += 1;
            if s.done {
                break;
            }
            assert!(steps < 200, "constant push should topple the pole");
        }
        assert!(steps > 5, "shouldn't topple instantly");
    }

    #[test]
    fn alternating_policy_survives_longer_than_constant() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(1);
        // Simple reactive policy: push in the direction the pole leans.
        env.reset(&mut rng);
        let mut obs = env.state.to_vec();
        let mut steps = 0;
        loop {
            let a = if obs[2] > 0.0 { 1.0 } else { 0.0 };
            let s = env.step(&[a], &mut rng);
            obs = s.obs;
            steps += 1;
            if s.done || s.truncated {
                break;
            }
        }
        assert!(steps > 25, "reactive policy too weak: {steps}");
    }

    #[test]
    fn reward_is_one_per_step() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let s = env.step(&[0.0], &mut rng);
        assert_eq!(s.reward, 1.0);
    }
}
