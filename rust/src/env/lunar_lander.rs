//! LunarLanderLite-v0 — our substitute for gym's Box2D LunarLander-v2
//! (the paper's continuous-action benchmark).
//!
//! Gym's version needs the Box2D physics engine; we implement a 2-D
//! rigid-body lander with the same observation layout (x, y, vx, vy,
//! angle, angular velocity, left-leg contact, right-leg contact), the
//! same action semantics (continuous: main throttle + lateral throttle;
//! discrete wrapper available), and a reward shaped the same way
//! (distance + velocity + angle potential, contact bonuses, fuel costs,
//! ±100 terminal). No terrain variation — the pad is flat at y=0 — which
//! preserves the control problem (soft touchdown under gravity with
//! noisy initial conditions) while dropping the polygon collision code
//! that contributes nothing to replay-buffer behaviour.

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;

const GRAVITY: f32 = -1.625; // moon-ish, matches gym scale after normalization
const DT: f32 = 1.0 / 50.0;
const MAIN_POWER: f32 = 6.0;
const SIDE_POWER: f32 = 0.6;
const ANG_DAMP: f32 = 0.05;
const LEG_Y: f32 = 0.12; // leg height below hull center
const PAD_HALF_WIDTH: f32 = 0.4;

pub struct LunarLanderLite {
    spec: EnvSpec,
    // Hull state.
    x: f32,
    y: f32,
    vx: f32,
    vy: f32,
    angle: f32,
    vang: f32,
    left_contact: bool,
    right_contact: bool,
    steps: usize,
    prev_shaping: Option<f32>,
}

impl LunarLanderLite {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "LunarLanderLite-v0",
                obs_dim: 8,
                action_space: ActionSpace::Continuous { dim: 2, low: -1.0, high: 1.0 },
                max_episode_steps: 1000,
                solved_reward: 200.0,
            },
            x: 0.0,
            y: 0.0,
            vx: 0.0,
            vy: 0.0,
            angle: 0.0,
            vang: 0.0,
            left_contact: false,
            right_contact: false,
            steps: 0,
            prev_shaping: None,
        }
    }

    fn obs(&self) -> Vec<f32> {
        vec![
            self.x,
            self.y,
            self.vx,
            self.vy,
            self.angle,
            self.vang,
            self.left_contact as u32 as f32,
            self.right_contact as u32 as f32,
        ]
    }

    /// Gym's potential-based shaping term.
    fn shaping(&self) -> f32 {
        -100.0 * (self.x * self.x + self.y * self.y).sqrt()
            - 100.0 * (self.vx * self.vx + self.vy * self.vy).sqrt()
            - 100.0 * self.angle.abs()
            + 10.0 * self.left_contact as u32 as f32
            + 10.0 * self.right_contact as u32 as f32
    }
}

impl Default for LunarLanderLite {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for LunarLanderLite {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.x = rng.range_f32(-0.3, 0.3);
        self.y = 1.4;
        self.vx = rng.range_f32(-0.3, 0.3);
        self.vy = rng.range_f32(-0.2, 0.0);
        self.angle = rng.range_f32(-0.2, 0.2);
        self.vang = rng.range_f32(-0.2, 0.2);
        self.left_contact = false;
        self.right_contact = false;
        self.steps = 0;
        self.prev_shaping = Some(self.shaping());
        self.obs()
    }

    fn step(&mut self, action: &[f32], rng: &mut Rng) -> Step {
        // Continuous semantics per gym: main ∈ [-1,1] fires when > 0 with
        // throttle 0.5..1.0; lateral fires when |a|>0.5.
        let main_cmd = action[0].clamp(-1.0, 1.0);
        let side_cmd = action.get(1).copied().unwrap_or(0.0).clamp(-1.0, 1.0);
        let main = if main_cmd > 0.0 { 0.5 + 0.5 * main_cmd } else { 0.0 };
        let side = if side_cmd.abs() > 0.5 {
            side_cmd.signum() * (side_cmd.abs() - 0.5) * 2.0
        } else {
            0.0
        };

        // Thruster dispersion noise (Box2D's particle impulse jitter).
        let jitter = 1.0 + rng.range_f32(-0.05, 0.05);
        let (sin_a, cos_a) = self.angle.sin_cos();
        // Main engine pushes along the hull's up axis.
        let ax = -sin_a * MAIN_POWER * main * jitter + cos_a * SIDE_POWER * side;
        let ay = cos_a * MAIN_POWER * main * jitter + sin_a * SIDE_POWER * side + GRAVITY;
        self.vx += ax * DT;
        self.vy += ay * DT;
        self.x += self.vx * DT;
        self.y += self.vy * DT;
        // Side engine also torques the hull; damping keeps it stable.
        self.vang += (-side * 1.2 - ANG_DAMP * self.vang) * DT
            + rng.range_f32(-0.002, 0.002);
        self.angle += self.vang * DT;

        // Leg contact: hull bottom reaches the ground plane.
        let ground = self.y - LEG_Y <= 0.0;
        self.left_contact = ground;
        self.right_contact = ground;

        self.steps += 1;
        let mut reward = 0.0f32;
        let shaping = self.shaping();
        if let Some(prev) = self.prev_shaping {
            reward += shaping - prev;
        }
        self.prev_shaping = Some(shaping);
        reward -= main * 0.30; // fuel
        reward -= side.abs() * 0.03;

        let mut done = false;
        // Crash: hit ground too fast / too tilted, or flew away.
        if ground {
            done = true;
            let soft = self.vy.abs() < 0.5 && self.vx.abs() < 0.5 && self.angle.abs() < 0.35;
            let on_pad = self.x.abs() <= PAD_HALF_WIDTH;
            if soft && on_pad {
                reward += 100.0;
            } else {
                reward -= 100.0;
            }
        } else if self.x.abs() > 1.5 || self.y > 2.5 {
            done = true;
            reward -= 100.0;
        }
        Step {
            obs: self.obs(),
            reward,
            done,
            truncated: !done && self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_fall_crashes_with_penalty() {
        let mut env = LunarLanderLite::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        env.vy = -1.5; // already falling fast
        let mut total = 0.0;
        let mut done = false;
        for _ in 0..1000 {
            let s = env.step(&[-1.0, 0.0], &mut rng);
            total += s.reward;
            if s.done {
                done = true;
                break;
            }
        }
        assert!(done, "must hit the ground");
        assert!(total < -50.0, "crash must be punished: {total}");
    }

    #[test]
    fn proportional_controller_lands_softly() {
        // Hand controller: thrust against vertical speed, steer to center.
        let mut env = LunarLanderLite::new();
        let mut rng = Rng::new(1);
        let mut wins = 0;
        for _ in 0..5 {
            let mut obs = env.reset(&mut rng);
            let mut total = 0.0;
            loop {
                let target_vy = -0.25 - 0.1 * obs[1];
                let main = ((target_vy - obs[3]) * 3.0).clamp(-1.0, 1.0);
                let side = (-obs[0] * 0.8 - obs[2] * 1.2 + obs[4] * 2.0).clamp(-1.0, 1.0);
                let s = env.step(&[main, side], &mut rng);
                total += s.reward;
                obs = s.obs;
                if s.done || s.truncated {
                    break;
                }
            }
            if total > 0.0 {
                wins += 1;
            }
        }
        assert!(wins >= 3, "controller should usually land: {wins}/5");
    }

    #[test]
    fn fuel_costs_reduce_reward() {
        let mut env = LunarLanderLite::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        env.vy = 0.0;
        let s = env.step(&[1.0, 0.0], &mut rng);
        // Shaping may dominate, but fuel term must be present in the sum:
        // compare with a no-thrust step from identical state.
        let mut env2 = LunarLanderLite::new();
        env2.reset(&mut Rng::new(2));
        env2.vy = 0.0;
        let _ = (s, env2);
    }
}
