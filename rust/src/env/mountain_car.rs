//! MountainCar-v0 (discrete) and MountainCarContinuous-v0, dynamics
//! identical to the gym classic-control implementations.

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;

const MIN_POS: f32 = -1.2;
const MAX_POS: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POS: f32 = 0.5;
const FORCE: f32 = 0.001;
const GRAVITY: f32 = 0.0025;

pub struct MountainCar {
    spec: EnvSpec,
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCar {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "MountainCar-v0",
                obs_dim: 2,
                action_space: ActionSpace::Discrete(3),
                max_episode_steps: 200,
                solved_reward: -110.0,
            },
            pos: 0.0,
            vel: 0.0,
            steps: 0,
        }
    }
}

impl Default for MountainCar {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for MountainCar {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = rng.range_f32(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        vec![self.pos, self.vel]
    }

    fn step(&mut self, action: &[f32], _rng: &mut Rng) -> Step {
        let a = action[0].round().clamp(0.0, 2.0) as i32;
        self.vel += (a - 1) as f32 * FORCE + (3.0 * self.pos).cos() * (-GRAVITY);
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos = (self.pos + self.vel).clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0;
        }
        self.steps += 1;
        let done = self.pos >= GOAL_POS;
        Step {
            obs: vec![self.pos, self.vel],
            reward: -1.0,
            done,
            truncated: !done && self.steps >= self.spec.max_episode_steps,
        }
    }
}

const C_POWER: f32 = 0.0015;

pub struct MountainCarContinuous {
    spec: EnvSpec,
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCarContinuous {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "MountainCarContinuous-v0",
                obs_dim: 2,
                action_space: ActionSpace::Continuous { dim: 1, low: -1.0, high: 1.0 },
                max_episode_steps: 999,
                solved_reward: 90.0,
            },
            pos: 0.0,
            vel: 0.0,
            steps: 0,
        }
    }
}

impl Default for MountainCarContinuous {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for MountainCarContinuous {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = rng.range_f32(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        vec![self.pos, self.vel]
    }

    fn step(&mut self, action: &[f32], _rng: &mut Rng) -> Step {
        let force = action[0].clamp(-1.0, 1.0);
        self.vel += force * C_POWER - 0.0025 * (3.0 * self.pos).cos();
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos = (self.pos + self.vel).clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0;
        }
        self.steps += 1;
        let done = self.pos >= 0.45; // gym's continuous goal
        let reward = if done { 100.0 } else { -0.1 * force * force };
        Step {
            obs: vec![self.pos, self.vel],
            reward,
            done,
            truncated: !done && self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stationary_policy_never_reaches_goal() {
        let mut env = MountainCar::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        for _ in 0..200 {
            let s = env.step(&[1.0], &mut rng); // no-op action
            assert!(!s.done);
            if s.truncated {
                break;
            }
        }
    }

    #[test]
    fn bang_bang_policy_reaches_goal() {
        // Oscillation pumping: push in the direction of velocity.
        let mut env = MountainCar::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut done = false;
        for _ in 0..200 {
            let a = if env.vel >= 0.0 { 2.0 } else { 0.0 };
            let s = env.step(&[a], &mut rng);
            if s.done {
                done = true;
                break;
            }
        }
        assert!(done, "bang-bang should solve MountainCar");
    }

    #[test]
    fn continuous_goal_pays_bonus() {
        let mut env = MountainCarContinuous::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let mut total = 0.0;
        let mut done = false;
        for _ in 0..999 {
            let a = if env.vel >= 0.0 { 1.0 } else { -1.0 };
            let s = env.step(&[a], &mut rng);
            total += s.reward;
            if s.done {
                done = true;
                break;
            }
        }
        assert!(done);
        assert!(total > 60.0, "total {total}");
    }

    #[test]
    fn position_clamped_at_left_wall() {
        let mut env = MountainCar::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        for _ in 0..300 {
            env.step(&[0.0], &mut rng); // push left forever
            assert!(env.pos >= MIN_POS);
        }
    }
}
