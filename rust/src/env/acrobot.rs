//! Acrobot-v1: swing a two-link pendulum's tip above the bar. Dynamics
//! per Sutton & Barto / gym's `AcrobotEnv` (book parametrization, RK4).

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;
use std::f32::consts::PI;

const DT: f32 = 0.2;
const L1: f32 = 1.0;
const M1: f32 = 1.0;
const M2: f32 = 1.0;
const LC1: f32 = 0.5;
const LC2: f32 = 0.5;
const I1: f32 = 1.0;
const I2: f32 = 1.0;
const G: f32 = 9.8;
const MAX_VEL1: f32 = 4.0 * PI;
const MAX_VEL2: f32 = 9.0 * PI;

pub struct Acrobot {
    spec: EnvSpec,
    s: [f32; 4], // theta1, theta2, dtheta1, dtheta2
    steps: usize,
}

impl Acrobot {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "Acrobot-v1",
                obs_dim: 6,
                action_space: ActionSpace::Discrete(3),
                max_episode_steps: 500,
                solved_reward: -100.0,
            },
            s: [0.0; 4],
            steps: 0,
        }
    }

    fn obs(&self) -> Vec<f32> {
        let [t1, t2, d1, d2] = self.s;
        vec![t1.cos(), t1.sin(), t2.cos(), t2.sin(), d1, d2]
    }
}

impl Default for Acrobot {
    fn default() -> Self {
        Self::new()
    }
}

fn wrap(x: f32, lo: f32, hi: f32) -> f32 {
    let range = hi - lo;
    let mut x = x;
    while x > hi {
        x -= range;
    }
    while x < lo {
        x += range;
    }
    x
}

/// Equations of motion (gym `_dsdt`), torque on the second joint.
fn dsdt(s: [f32; 4], torque: f32) -> [f32; 4] {
    let [theta1, theta2, dtheta1, dtheta2] = s;
    let d1 = M1 * LC1 * LC1
        + M2 * (L1 * L1 + LC2 * LC2 + 2.0 * L1 * LC2 * theta2.cos())
        + I1
        + I2;
    let d2 = M2 * (LC2 * LC2 + L1 * LC2 * theta2.cos()) + I2;
    let phi2 = M2 * LC2 * G * (theta1 + theta2 - PI / 2.0).cos();
    let phi1 = -M2 * L1 * LC2 * dtheta2 * dtheta2 * theta2.sin()
        - 2.0 * M2 * L1 * LC2 * dtheta2 * dtheta1 * theta2.sin()
        + (M1 * LC1 + M2 * L1) * G * (theta1 - PI / 2.0).cos()
        + phi2;
    // Book variant (gym default).
    let ddtheta2 = (torque + d2 / d1 * phi1
        - M2 * L1 * LC2 * dtheta1 * dtheta1 * theta2.sin()
        - phi2)
        / (M2 * LC2 * LC2 + I2 - d2 * d2 / d1);
    let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
    [dtheta1, dtheta2, ddtheta1, ddtheta2]
}

/// One RK4 step of the dynamics.
fn rk4(s: [f32; 4], torque: f32, dt: f32) -> [f32; 4] {
    let add = |a: [f32; 4], b: [f32; 4], k: f32| {
        [a[0] + k * b[0], a[1] + k * b[1], a[2] + k * b[2], a[3] + k * b[3]]
    };
    let k1 = dsdt(s, torque);
    let k2 = dsdt(add(s, k1, dt / 2.0), torque);
    let k3 = dsdt(add(s, k2, dt / 2.0), torque);
    let k4 = dsdt(add(s, k3, dt), torque);
    [
        s[0] + dt / 6.0 * (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]),
        s[1] + dt / 6.0 * (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]),
        s[2] + dt / 6.0 * (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]),
        s[3] + dt / 6.0 * (k1[3] + 2.0 * k2[3] + 2.0 * k3[3] + k4[3]),
    ]
}

impl Env for Acrobot {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        for v in self.s.iter_mut() {
            *v = rng.range_f32(-0.1, 0.1);
        }
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32], _rng: &mut Rng) -> Step {
        let torque = action[0].round().clamp(0.0, 2.0) - 1.0; // {-1, 0, +1}
        let mut ns = rk4(self.s, torque, DT);
        ns[0] = wrap(ns[0], -PI, PI);
        ns[1] = wrap(ns[1], -PI, PI);
        ns[2] = ns[2].clamp(-MAX_VEL1, MAX_VEL1);
        ns[3] = ns[3].clamp(-MAX_VEL2, MAX_VEL2);
        self.s = ns;
        self.steps += 1;
        let done = -self.s[0].cos() - (self.s[1] + self.s[0]).cos() > 1.0;
        Step {
            obs: self.obs(),
            reward: if done { 0.0 } else { -1.0 },
            done,
            truncated: !done && self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hangs_near_rest_without_torque() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        for _ in 0..100 {
            let s = env.step(&[1.0], &mut rng); // zero torque
            assert!(!s.done, "must not solve itself at rest");
        }
    }

    #[test]
    fn energy_pumping_solves_eventually() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        // Torque with the total angular velocity (energy pumping).
        let mut done = false;
        for _ in 0..500 {
            let a = if env.s[2] + env.s[3] >= 0.0 { 2.0 } else { 0.0 };
            let s = env.step(&[a], &mut rng);
            if s.done {
                done = true;
                break;
            }
        }
        assert!(done, "energy pumping should raise the tip");
    }

    #[test]
    fn velocities_bounded() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        for i in 0..300 {
            let a = if i % 7 < 4 { 2.0 } else { 0.0 };
            let s = env.step(&[a], &mut rng);
            assert!(env.s[2].abs() <= MAX_VEL1 + 1e-4);
            assert!(env.s[3].abs() <= MAX_VEL2 + 1e-4);
            if s.done || s.truncated {
                env.reset(&mut rng);
            }
        }
    }
}
