//! Pendulum-v1: swing up and hold an underactuated pendulum. Dynamics and
//! constants identical to `gym.envs.classic_control.PendulumEnv`.

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;
use std::f32::consts::PI;

const MAX_SPEED: f32 = 8.0;
const MAX_TORQUE: f32 = 2.0;
const DT: f32 = 0.05;
const G: f32 = 10.0;
const M: f32 = 1.0;
const L: f32 = 1.0;

pub struct Pendulum {
    spec: EnvSpec,
    theta: f32,
    theta_dot: f32,
    steps: usize,
}

impl Pendulum {
    pub fn new() -> Self {
        Self {
            spec: EnvSpec {
                name: "Pendulum-v1",
                obs_dim: 3,
                action_space: ActionSpace::Continuous {
                    dim: 1,
                    low: -MAX_TORQUE,
                    high: MAX_TORQUE,
                },
                max_episode_steps: 200,
                // Gym has no "solved" threshold; ≥ -250 avg is good policy.
                solved_reward: -250.0,
            },
            theta: 0.0,
            theta_dot: 0.0,
            steps: 0,
        }
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.theta.cos(), self.theta.sin(), self.theta_dot]
    }
}

impl Default for Pendulum {
    fn default() -> Self {
        Self::new()
    }
}

fn angle_normalize(x: f32) -> f32 {
    let two_pi = 2.0 * PI;
    ((x + PI).rem_euclid(two_pi)) - PI
}

impl Env for Pendulum {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.theta = rng.range_f32(-PI, PI);
        self.theta_dot = rng.range_f32(-1.0, 1.0);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32], _rng: &mut Rng) -> Step {
        let u = action[0].clamp(-MAX_TORQUE, MAX_TORQUE);
        let th = self.theta;
        let thdot = self.theta_dot;
        let cost = angle_normalize(th).powi(2) + 0.1 * thdot * thdot + 0.001 * u * u;
        let new_thdot = (thdot
            + (3.0 * G / (2.0 * L) * th.sin() + 3.0 / (M * L * L) * u) * DT)
            .clamp(-MAX_SPEED, MAX_SPEED);
        self.theta = th + new_thdot * DT;
        self.theta_dot = new_thdot;
        self.steps += 1;
        Step {
            obs: self.obs(),
            reward: -cost,
            done: false, // pendulum never terminates
            truncated: self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_bounded_and_negative() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        for _ in 0..100 {
            let s = env.step(&[rng.range_f32(-2.0, 2.0)], &mut rng);
            assert!(s.reward <= 0.0);
            assert!(s.reward >= -17.0); // gym's documented bound ≈ -16.27
            if s.truncated {
                env.reset(&mut rng);
            }
        }
    }

    #[test]
    fn upright_no_torque_is_near_zero_cost() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(4);
        env.reset(&mut rng);
        env.theta = 0.0;
        env.theta_dot = 0.0;
        let s = env.step(&[0.0], &mut rng);
        assert!(s.reward > -0.01, "{}", s.reward);
    }

    #[test]
    fn velocity_clamped() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(5);
        env.reset(&mut rng);
        for _ in 0..400 {
            env.step(&[MAX_TORQUE], &mut rng);
            assert!(env.theta_dot.abs() <= MAX_SPEED + 1e-5);
        }
    }

    #[test]
    fn obs_is_unit_circle_plus_velocity() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(6);
        let obs = env.reset(&mut rng);
        let norm = obs[0] * obs[0] + obs[1] * obs[1];
        assert!((norm - 1.0).abs() < 1e-5);
    }
}
