//! RandomMDP-v0 — synthetic tabular-ish MDP with a tunable per-step
//! compute cost.
//!
//! Used by the throughput benches: the paper's framework inputs are "the
//! throughput of data collection vs cores" (§V-C), which depends on the
//! simulator's step cost. `RandomMdp` lets the benches sweep that cost
//! (`busy_work_iters`) to reproduce the Fig 12 profiles for fast and slow
//! simulators alike.

use super::{ActionSpace, Env, EnvSpec, Step};
use crate::util::rng::Rng;

pub struct RandomMdp {
    spec: EnvSpec,
    n_states: usize,
    state: usize,
    steps: usize,
    /// Extra floating-point work per step (simulator cost knob).
    busy_work_iters: usize,
    sink: f32,
}

impl RandomMdp {
    /// `n_states` tabular states observed as a one-hot-ish dense vector of
    /// dimension min(n_states, 16); `n_actions` discrete actions.
    pub fn new(n_states: usize, n_actions: usize, busy_work_iters: usize) -> Self {
        assert!(n_states >= 2 && n_actions >= 2);
        let obs_dim = n_states.min(16);
        Self {
            spec: EnvSpec {
                name: "RandomMDP-v0",
                obs_dim,
                action_space: ActionSpace::Discrete(n_actions),
                max_episode_steps: 128,
                solved_reward: f32::INFINITY, // no notion of solved
            },
            n_states,
            state: 0,
            steps: 0,
            busy_work_iters,
            sink: 0.0,
        }
    }

    fn obs(&self) -> Vec<f32> {
        let mut o = vec![0.0; self.spec.obs_dim];
        o[self.state % self.spec.obs_dim] = 1.0;
        o[(self.state / self.spec.obs_dim) % self.spec.obs_dim] += 0.5;
        o
    }
}

impl Env for RandomMdp {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.state = rng.below_usize(self.n_states);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32], rng: &mut Rng) -> Step {
        // Tunable simulator cost.
        let mut acc = self.sink;
        for i in 0..self.busy_work_iters {
            acc += ((i as f32) * 1.001 + acc).sin();
        }
        self.sink = acc * 1e-30;

        let a = action[0] as usize;
        self.state = (self.state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(a + 1)
            ^ rng.below_usize(4))
            % self.n_states;
        self.steps += 1;
        let reward = ((self.state % 7) as f32 - 3.0) / 3.0 + self.sink;
        let done = self.state == 0;
        Step {
            obs: self.obs(),
            reward,
            done,
            truncated: !done && self.steps >= self.spec.max_episode_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episodes_terminate() {
        let mut env = RandomMdp::new(16, 4, 0);
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        let mut endings = 0;
        for _ in 0..2000 {
            let s = env.step(&[rng.below_usize(4) as f32], &mut rng);
            if s.done || s.truncated {
                endings += 1;
                env.reset(&mut rng);
            }
        }
        assert!(endings > 5);
    }

    #[test]
    fn busy_work_scales_cost() {
        use std::time::Instant;
        let mut rng = Rng::new(1);
        let mut cheap = RandomMdp::new(16, 4, 0);
        let mut costly = RandomMdp::new(16, 4, 20_000);
        cheap.reset(&mut rng);
        costly.reset(&mut rng);
        let t0 = Instant::now();
        for _ in 0..200 {
            if cheap.step(&[0.0], &mut rng).done {
                cheap.reset(&mut rng);
            }
        }
        let cheap_t = t0.elapsed();
        let t1 = Instant::now();
        for _ in 0..200 {
            if costly.step(&[0.0], &mut rng).done {
                costly.reset(&mut rng);
            }
        }
        let costly_t = t1.elapsed();
        assert!(costly_t > cheap_t * 3, "{cheap_t:?} vs {costly_t:?}");
    }
}
