//! Adam optimizer over flat f32 vectors (Kingma & Ba 2015).
//!
//! Operates on contiguous *groups* `[lo, hi)` of the flat parameter
//! vector with an independent bias-correction step counter per group
//! (learn graphs update the actor and critic slices at different rates —
//! TD3's delayed policy updates, for instance).

use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Optional global-norm gradient clip (0 disables).
    pub grad_clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self { lr: 3e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8, grad_clip: 0.0 }
    }
}

pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    steps: BTreeMap<(usize, usize), u64>,
}

impl Adam {
    pub fn new(dim: usize, cfg: AdamConfig) -> Self {
        Self { cfg, m: vec![0.0; dim], v: vec![0.0; dim], steps: BTreeMap::new() }
    }

    pub fn config(&self) -> AdamConfig {
        self.cfg
    }

    /// Apply one Adam step to `params` (= the `[lo, hi)` slice of the
    /// flat vector) using `grad`.
    pub fn step(&mut self, lo: usize, hi: usize, grad: &[f32], params: &mut [f32]) {
        debug_assert_eq!(grad.len(), hi - lo);
        debug_assert_eq!(params.len(), hi - lo);
        let t = self.steps.entry((lo, hi)).or_insert(0);
        *t += 1;
        let t = *t as i32;
        let AdamConfig { lr, beta1, beta2, eps, grad_clip } = self.cfg;

        // Optional global-norm clip (on this group).
        let mut scale = 1.0f32;
        if grad_clip > 0.0 {
            let norm = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
            if norm > grad_clip {
                scale = grad_clip / norm;
            }
        }

        let bc1 = 1.0 - beta1.powi(t);
        let bc2 = 1.0 - beta2.powi(t);
        let m = &mut self.m[lo..hi];
        let v = &mut self.v[lo..hi];
        for i in 0..grad.len() {
            let g = grad[i] * scale;
            m[i] = beta1 * m[i] + (1.0 - beta1) * g;
            v[i] = beta2 * v[i] + (1.0 - beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }

    /// Step counter for a group (tests / diagnostics).
    pub fn group_steps(&self, lo: usize, hi: usize) -> u64 {
        self.steps.get(&(lo, hi)).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on f(x) = x² converges to 0 from x=5.
    #[test]
    fn minimizes_quadratic() {
        let mut adam = Adam::new(1, AdamConfig { lr: 0.1, ..Default::default() });
        let mut x = vec![5.0f32];
        for _ in 0..500 {
            let g = [2.0 * x[0]];
            adam.step(0, 1, &g, &mut x);
        }
        assert!(x[0].abs() < 0.05, "{}", x[0]);
    }

    /// First step moves by ~lr regardless of gradient magnitude.
    #[test]
    fn first_step_is_lr_sized() {
        for g0 in [1e-3f32, 1.0, 1e3] {
            let mut adam = Adam::new(1, AdamConfig { lr: 0.01, ..Default::default() });
            let mut x = vec![0.0f32];
            adam.step(0, 1, &[g0], &mut x);
            assert!((x[0] + 0.01).abs() < 1e-3, "g0={g0} x={}", x[0]);
        }
    }

    #[test]
    fn independent_group_counters() {
        let mut adam = Adam::new(4, AdamConfig::default());
        let mut p = vec![0.0f32; 4];
        adam.step(0, 2, &[1.0, 1.0], &mut p.clone()[0..2]);
        adam.step(0, 2, &[1.0, 1.0], &mut p[0..2]);
        adam.step(2, 4, &[1.0, 1.0], &mut p[2..4]);
        assert_eq!(adam.group_steps(0, 2), 2);
        assert_eq!(adam.group_steps(2, 4), 1);
        assert_eq!(adam.group_steps(0, 4), 0);
    }

    #[test]
    fn grad_clip_bounds_update() {
        let cfg = AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() };
        let mut adam = Adam::new(2, cfg);
        let mut x = vec![0.0f32; 2];
        adam.step(0, 2, &[1e6, 1e6], &mut x);
        // With clipping the effective gradient is unit-norm; the update
        // stays ~lr-sized.
        assert!(x.iter().all(|v| v.abs() < 0.2), "{x:?}");
    }
}
