//! Weight checkpointing: save/restore the parameter server's state so
//! training runs can resume and trained policies can be evaluated later.
//!
//! Format (little-endian): magic "PALCKPT1", u64 dim, u64 opt_steps,
//! online f32[dim], target f32[dim], trailing crc32 of the payload.

use super::ParameterServer;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PALCKPT1";

/// Serialized training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub online: Vec<f32>,
    pub target: Vec<f32>,
    pub opt_steps: u64,
}

fn crc32(data: &[u8]) -> u32 {
    // Small table-free CRC-32 (IEEE), enough for corruption detection.
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Checkpoint {
    /// Capture the current server state.
    pub fn from_server(server: &ParameterServer) -> Self {
        Self {
            online: server.online_copy(),
            target: server.target_copy(),
            opt_steps: server.opt_steps() as u64,
        }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut payload = Vec::with_capacity(16 + 8 * self.online.len());
        payload.extend_from_slice(&(self.online.len() as u64).to_le_bytes());
        payload.extend_from_slice(&self.opt_steps.to_le_bytes());
        for v in self.online.iter().chain(&self.target) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let crc = crc32(&payload);
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < MAGIC.len() + 16 + 4 || &bytes[..8] != MAGIC {
            bail!("not a PAL checkpoint: {}", path.as_ref().display());
        }
        let payload = &bytes[8..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(payload) != stored_crc {
            bail!("checkpoint corrupted (crc mismatch): {}", path.as_ref().display());
        }
        let dim = u64::from_le_bytes(payload[0..8].try_into().unwrap()) as usize;
        let opt_steps = u64::from_le_bytes(payload[8..16].try_into().unwrap());
        let need = 16 + dim * 8;
        if payload.len() != need {
            bail!("checkpoint truncated: payload {} bytes, want {need}", payload.len());
        }
        let floats: Vec<f32> = payload[16..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self {
            online: floats[..dim].to_vec(),
            target: floats[dim..].to_vec(),
            opt_steps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{AdamConfig, TargetSync};

    #[test]
    fn roundtrip() {
        let server = ParameterServer::new(
            vec![1.0, 2.0, -3.5, 0.25],
            AdamConfig::default(),
            TargetSync::None,
            1,
        );
        server.push_gradient(0, 4, &[0.1; 4]);
        let ck = Checkpoint::from_server(&server);
        let path = std::env::temp_dir().join("pal_ckpt_test.bin");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, loaded);
        assert_eq!(loaded.opt_steps, 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corruption() {
        let ck = Checkpoint { online: vec![1.0; 8], target: vec![2.0; 8], opt_steps: 3 };
        let path = std::env::temp_dir().join("pal_ckpt_corrupt.bin");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let path = std::env::temp_dir().join("pal_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::write(&path, b"PALCKPT1").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
