//! Weight checkpointing: save/restore the parameter server's state so
//! training runs can resume and trained policies can be evaluated later.
//!
//! Format (little-endian): magic "PALCKPT1", u64 dim, u64 opt_steps,
//! online f32[dim], target f32[dim], trailing crc32 of the payload.
//! Magic/crc validation and the atomic temp-file + rename write are the
//! shared [`crate::util::blob`] helpers — the same ones the replay-state
//! checkpoint ([`crate::service::checkpoint`]) uses, so the two loaders
//! cannot drift apart in how they reject corrupt files.

use super::ParameterServer;
use crate::util::blob::{read_blob, write_blob, ByteReader};
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PALCKPT1";

/// Serialized training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub online: Vec<f32>,
    pub target: Vec<f32>,
    pub opt_steps: u64,
}

impl Checkpoint {
    /// Capture the current server state.
    pub fn from_server(server: &ParameterServer) -> Self {
        Self {
            online: server.online_copy(),
            target: server.target_copy(),
            opt_steps: server.opt_steps() as u64,
        }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut payload = Vec::with_capacity(16 + 8 * self.online.len());
        payload.extend_from_slice(&(self.online.len() as u64).to_le_bytes());
        payload.extend_from_slice(&self.opt_steps.to_le_bytes());
        for v in self.online.iter().chain(&self.target) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        write_blob(path.as_ref(), MAGIC, &payload)
            .with_context(|| format!("writing checkpoint {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let payload = read_blob(path, MAGIC)
            .with_context(|| format!("not a PAL checkpoint: {}", path.display()))?;
        let mut r = ByteReader::new(&payload);
        let dim = r.u64("dim")? as usize;
        let opt_steps = r.u64("opt_steps")?;
        // Checked arithmetic: a corrupted `dim` must be a clean error,
        // never an overflow or a capacity-overflow panic in Vec.
        let want = dim
            .checked_mul(8)
            .and_then(|b| b.checked_add(16))
            .filter(|&w| w == payload.len());
        if want.is_none() {
            bail!(
                "checkpoint truncated or dim corrupted: payload {} bytes, dim {dim}",
                payload.len()
            );
        }
        let mut online = Vec::with_capacity(dim);
        let mut target = Vec::with_capacity(dim);
        for _ in 0..dim {
            online.push(r.f32("online")?);
        }
        for _ in 0..dim {
            target.push(r.f32("target")?);
        }
        r.expect_end()?;
        Ok(Self { online, target, opt_steps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{AdamConfig, TargetSync};

    #[test]
    fn roundtrip() {
        let server = ParameterServer::new(
            vec![1.0, 2.0, -3.5, 0.25],
            AdamConfig::default(),
            TargetSync::None,
            1,
        );
        server.push_gradient(0, 4, &[0.1; 4]);
        let ck = Checkpoint::from_server(&server);
        let path = std::env::temp_dir().join("pal_ckpt_test.bin");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, loaded);
        assert_eq!(loaded.opt_steps, 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corruption() {
        let ck = Checkpoint { online: vec![1.0; 8], target: vec![2.0; 8], opt_steps: 3 };
        let path = std::env::temp_dir().join("pal_ckpt_corrupt.bin");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let path = std::env::temp_dir().join("pal_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::write(&path, b"PALCKPT1").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_forged_huge_dim_without_panic() {
        // A payload whose dim field would overflow `dim * 8` (with a
        // VALID crc — crc32 is not tamper-proof) must be a clean error,
        // not an arithmetic or allocation panic.
        let mut payload = Vec::new();
        payload.extend_from_slice(&u64::MAX.to_le_bytes()); // dim
        payload.extend_from_slice(&0u64.to_le_bytes()); // opt_steps
        let path = std::env::temp_dir().join("pal_ckpt_forged.bin");
        crate::util::blob::write_blob(&path, b"PALCKPT1", &payload).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left() {
        let ck = Checkpoint { online: vec![0.5; 4], target: vec![0.5; 4], opt_steps: 0 };
        let path = std::env::temp_dir().join("pal_ckpt_atomic.bin");
        ck.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn restore_into_server_resumes_opt_steps() {
        let server = ParameterServer::new(
            vec![1.0; 4],
            AdamConfig::default(),
            TargetSync::None,
            1,
        );
        server.push_gradient(0, 4, &[0.2; 4]);
        let ck = Checkpoint::from_server(&server);
        let fresh = ParameterServer::new(
            vec![0.0; 4],
            AdamConfig::default(),
            TargetSync::None,
            1,
        );
        let v0 = fresh.version();
        fresh.restore(&ck).unwrap();
        assert_eq!(fresh.online_copy(), ck.online);
        assert_eq!(fresh.target_copy(), ck.target);
        assert_eq!(fresh.opt_steps(), 1);
        assert!(fresh.version() > v0, "restore must bump the version");
        // Dimension mismatch must be rejected.
        let small = ParameterServer::new(
            vec![0.0; 2],
            AdamConfig::default(),
            TargetSync::None,
            1,
        );
        assert!(small.restore(&ck).is_err());
    }
}
