//! Parameter server (paper §V-B, [17]).
//!
//! Owns the canonical online and target weights as flat `f32` vectors.
//! Learners push (sub-)gradients; the server aggregates `aggregation`
//! of them and applies one Adam step per aggregate. Actors and learners
//! pull snapshots keyed by a monotonically increasing version so they
//! only copy when something changed.
//!
//! Gradients arrive per *group* (a contiguous slice of the flat vector —
//! e.g. TD3's critic slice vs actor slice); Adam keeps independent step
//! counters per group for correct bias correction.

pub mod adam;
pub mod checkpoint;

pub use adam::{Adam, AdamConfig};
pub use checkpoint::Checkpoint;

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Target-network synchronization policy (per algorithm).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TargetSync {
    /// Copy online → target every `every` optimizer steps (DQN/DDQN).
    Hard { every: usize },
    /// Polyak averaging θ' ← τθ + (1-τ)θ' after every step (DDPG/TD3/SAC).
    Polyak { tau: f32 },
    /// No target network.
    None,
}

struct Inner {
    online: Vec<f32>,
    target: Vec<f32>,
    adam: Adam,
    /// Pending gradient accumulation per group: (sum, count).
    pending: BTreeMap<(usize, usize), (Vec<f32>, usize)>,
    opt_steps: usize,
}

/// The parameter server.
pub struct ParameterServer {
    inner: Mutex<Inner>,
    version: AtomicU64,
    sync: TargetSync,
    aggregation: usize,
    dim: usize,
}

impl ParameterServer {
    /// `init`: initial flat parameter vector (target starts as a copy).
    /// `aggregation`: number of sub-gradients averaged per Adam step
    /// (1 = fully asynchronous).
    pub fn new(init: Vec<f32>, adam_cfg: AdamConfig, sync: TargetSync, aggregation: usize) -> Self {
        assert!(aggregation >= 1);
        let dim = init.len();
        Self {
            inner: Mutex::new(Inner {
                target: init.clone(),
                adam: Adam::new(dim, adam_cfg),
                online: init,
                pending: BTreeMap::new(),
                opt_steps: 0,
            }),
            version: AtomicU64::new(1),
            sync,
            aggregation,
            dim,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Current weight version (bumps on every applied update).
    #[inline]
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Copy online weights into `buf` if `buf_version` is stale.
    /// Returns the fresh version (or `buf_version` when unchanged).
    pub fn sync_online(&self, buf: &mut Vec<f32>, buf_version: u64) -> u64 {
        let v = self.version();
        if v == buf_version && buf.len() == self.dim {
            return v;
        }
        let g = self.inner.lock().unwrap();
        buf.clear();
        buf.extend_from_slice(&g.online);
        // Version may have advanced while copying; report what we saw
        // before the copy (conservative staleness).
        v
    }

    /// Copy both online and target weights (learner snapshot).
    pub fn sync_pair(&self, online: &mut Vec<f32>, target: &mut Vec<f32>, buf_version: u64) -> u64 {
        let v = self.version();
        if v == buf_version && online.len() == self.dim {
            return v;
        }
        let g = self.inner.lock().unwrap();
        online.clear();
        online.extend_from_slice(&g.online);
        target.clear();
        target.extend_from_slice(&g.target);
        v
    }

    /// Push one sub-gradient for the flat range `[lo, hi)` (element
    /// offsets). Applies an Adam step once `aggregation` sub-gradients
    /// for that group have arrived. Returns true if a step was applied.
    pub fn push_gradient(&self, lo: usize, hi: usize, grad: &[f32]) -> bool {
        assert_eq!(grad.len(), hi - lo, "gradient length mismatch");
        assert!(hi <= self.dim);
        let mut g = self.inner.lock().unwrap();
        let agg = self.aggregation;
        let entry = g
            .pending
            .entry((lo, hi))
            .or_insert_with(|| (vec![0.0; hi - lo], 0));
        for (s, &x) in entry.0.iter_mut().zip(grad) {
            *s += x;
        }
        entry.1 += 1;
        if entry.1 < agg {
            return false;
        }
        // Take the aggregate and apply.
        let (mut sum, count) = g.pending.remove(&(lo, hi)).unwrap();
        if count > 1 {
            let inv = 1.0 / count as f32;
            for s in sum.iter_mut() {
                *s *= inv;
            }
        }
        {
            let Inner { online, adam, .. } = &mut *g;
            adam.step(lo, hi, &sum, &mut online[lo..hi]);
        }
        g.opt_steps += 1;
        match self.sync {
            TargetSync::Hard { every } => {
                if g.opt_steps % every.max(1) == 0 {
                    let Inner { online, target, .. } = &mut *g;
                    target.copy_from_slice(online);
                }
            }
            TargetSync::Polyak { tau } => {
                let Inner { online, target, .. } = &mut *g;
                for (t, &o) in target[lo..hi].iter_mut().zip(&online[lo..hi]) {
                    *t = tau * o + (1.0 - tau) * *t;
                }
            }
            TargetSync::None => {}
        }
        drop(g);
        self.version.fetch_add(1, Ordering::AcqRel);
        true
    }

    /// Force target ← online (used at initialization / warmup end).
    pub fn hard_sync_target(&self) {
        let mut g = self.inner.lock().unwrap();
        let Inner { online, target, .. } = &mut *g;
        target.copy_from_slice(online);
        drop(g);
        self.version.fetch_add(1, Ordering::AcqRel);
    }

    /// Optimizer steps applied so far.
    pub fn opt_steps(&self) -> usize {
        self.inner.lock().unwrap().opt_steps
    }

    /// Overwrite the server with a loaded [`Checkpoint`]: online and
    /// target weights plus the optimizer step count, bumping the version
    /// so every worker re-pulls. Adam moment vectors are NOT part of the
    /// checkpoint format; they warm back up over the first few steps of
    /// the resumed run. Pending (partially aggregated) gradients are
    /// dropped.
    pub fn restore(&self, ck: &Checkpoint) -> Result<()> {
        if ck.online.len() != self.dim || ck.target.len() != self.dim {
            bail!(
                "checkpoint dim mismatch: file has {} online / {} target params, server has {}",
                ck.online.len(),
                ck.target.len(),
                self.dim
            );
        }
        let mut g = self.inner.lock().unwrap();
        g.online.copy_from_slice(&ck.online);
        g.target.copy_from_slice(&ck.target);
        g.opt_steps = ck.opt_steps as usize;
        g.pending.clear();
        drop(g);
        self.version.fetch_add(1, Ordering::AcqRel);
        Ok(())
    }

    /// Read-only copy of the online weights (tests / checkpoints).
    pub fn online_copy(&self) -> Vec<f32> {
        self.inner.lock().unwrap().online.clone()
    }

    /// Read-only copy of the target weights.
    pub fn target_copy(&self) -> Vec<f32> {
        self.inner.lock().unwrap().target.clone()
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    fn server(n: usize, sync: TargetSync, agg: usize) -> ParameterServer {
        ParameterServer::new(vec![1.0; n], AdamConfig { lr: 0.1, ..Default::default() }, sync, agg)
    }

    #[test]
    fn gradient_step_moves_weights_down() {
        let s = server(4, TargetSync::None, 1);
        let v0 = s.version();
        assert!(s.push_gradient(0, 4, &[1.0; 4]));
        assert!(s.version() > v0);
        let w = s.online_copy();
        assert!(w.iter().all(|&x| x < 1.0), "{w:?}");
    }

    #[test]
    fn aggregation_waits_for_k() {
        let s = server(2, TargetSync::None, 3);
        assert!(!s.push_gradient(0, 2, &[1.0, 1.0]));
        assert!(!s.push_gradient(0, 2, &[1.0, 1.0]));
        let before = s.online_copy();
        assert_eq!(before, vec![1.0, 1.0]);
        assert!(s.push_gradient(0, 2, &[1.0, 1.0]));
        assert!(s.online_copy()[0] < 1.0);
        assert_eq!(s.opt_steps(), 1);
    }

    #[test]
    fn slice_updates_leave_rest_untouched() {
        let s = server(6, TargetSync::None, 1);
        s.push_gradient(2, 4, &[1.0, 1.0]);
        let w = s.online_copy();
        assert_eq!(w[0], 1.0);
        assert_eq!(w[1], 1.0);
        assert!(w[2] < 1.0 && w[3] < 1.0);
        assert_eq!(w[4], 1.0);
        assert_eq!(w[5], 1.0);
    }

    #[test]
    fn hard_target_sync_every_2() {
        let s = server(2, TargetSync::Hard { every: 2 }, 1);
        s.push_gradient(0, 2, &[1.0, 1.0]);
        assert_eq!(s.target_copy(), vec![1.0, 1.0], "no sync after 1 step");
        s.push_gradient(0, 2, &[1.0, 1.0]);
        assert_eq!(s.target_copy(), s.online_copy(), "synced after 2 steps");
    }

    #[test]
    fn polyak_moves_target_fractionally() {
        let s = server(2, TargetSync::Polyak { tau: 0.5 }, 1);
        s.push_gradient(0, 2, &[1.0, 1.0]);
        let online = s.online_copy();
        let target = s.target_copy();
        for (o, t) in online.iter().zip(&target) {
            let expect = 0.5 * o + 0.5 * 1.0;
            assert!((t - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn snapshot_versioning_skips_fresh() {
        let s = server(3, TargetSync::None, 1);
        let mut buf = Vec::new();
        let v1 = s.sync_online(&mut buf, 0);
        assert_eq!(buf, vec![1.0; 3]);
        // No change -> same version, buffer untouched even if cleared.
        buf[0] = 99.0;
        let v2 = s.sync_online(&mut buf, v1);
        assert_eq!(v2, v1);
        assert_eq!(buf[0], 99.0, "fresh snapshot must not copy");
        s.push_gradient(0, 3, &[1.0; 3]);
        let v3 = s.sync_online(&mut buf, v2);
        assert!(v3 > v2);
        assert!(buf[0] < 1.0);
    }

    #[test]
    fn concurrent_pushes_consistent() {
        use std::sync::Arc;
        let s = Arc::new(server(8, TargetSync::Polyak { tau: 0.01 }, 1));
        std::thread::scope(|sc| {
            for t in 0..4 {
                let s = Arc::clone(&s);
                sc.spawn(move || {
                    for _ in 0..100 {
                        if t % 2 == 0 {
                            s.push_gradient(0, 4, &[0.01; 4]);
                        } else {
                            s.push_gradient(4, 8, &[-0.01; 4]);
                        }
                    }
                });
            }
        });
        assert_eq!(s.opt_steps(), 400);
        let w = s.online_copy();
        assert!(w[..4].iter().all(|&x| x < 1.0));
        assert!(w[4..].iter().all(|&x| x > 1.0));
        assert!(w.iter().all(|x| x.is_finite()));
    }
}
