//! `pal` — launcher for the Parallel Actors and Learners framework.
//!
//! Subcommands:
//!   train         run one training session (the paper's Fig 7 pipeline)
//!   serve         expose a replay service on a Unix socket (`--remote` target)
//!   dse           design-space exploration: pick actor/learner core split
//!   buffer-bench  quick replay-buffer micro-benchmark
//!   envs          list built-in environments
//!   info          show manifest contents

use anyhow::{anyhow, bail, ensure, Result};
use pal_rl::coordinator::{
    build_service, restore_run_state, save_run_state, train, BufferKind, TrainConfig,
};
use pal_rl::dse;
use pal_rl::env::ENV_NAMES;
use pal_rl::params::{AdamConfig, ParameterServer, TargetSync};
use pal_rl::remote::{RemoteClient, RemoteSampler, RemoteWriter, ReplayServer};
use pal_rl::replay::SampleBatch;
use pal_rl::runtime::Manifest;
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimitSpec, ReplayService, SampleOutcome,
    ServiceState, TableSpec, WriterStep, STATE_FILE,
};
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::Arc;

const TRAIN_FLAGS: &[&str] = &[
    "algo", "env", "artifacts", "actors", "learners", "steps", "warmup",
    "update-interval", "buffer", "capacity", "shards", "fanout", "alpha",
    "beta", "lr", "grad-clip", "aggregation", "seed", "stop-at-reward",
    "log-every", "curve-out", "eps-decay", "action-noise", "save-checkpoint",
    "n-step", "gamma-nstep", "tables", "rate-limit", "save-state",
    "restore-state", "checkpoint-every", "remote", "remote-batch",
];

fn usage() -> ! {
    eprintln!(
        "pal — Parallel Actors and Learners

USAGE:
  pal train --algo <dqn|ddqn|ddpg|td3|sac> --env <ENV> [options]
  pal serve --socket PATH [--obs-dim N] [--act-dim N] [table/buffer options]
  pal dse   --algo <A> --env <E> [--cores M] [--update-interval R] [--shards 1,2,4,8,16] [--rate-limit S]
  pal buffer-bench [--capacity N] [--fanout K] [--shards S] [--threads T] [--ops N]
  pal state-smoke --dir DIR --phase <collect|resume> [--items N] [--capacity N] [--shards S]
  pal remote-smoke --socket PATH [--items N] [--capacity N] [--shards S]
  pal envs
  pal info  [--artifacts DIR]

TRAIN OPTIONS:
  --actors N          parallel actors (default 1)
  --learners N        parallel learners (default 1)
  --steps N           total env steps (default 20000)
  --warmup N          env steps before learning starts (default 1000)
  --update-interval R env-steps per learn-step ratio (default 1.0)
  --buffer KIND       pal | baseline | uniform | emulated-python | emulated-binding
  --capacity N        replay capacity (default 100000)
  --shards S          replay shards, pal buffer only (default 1; >1 enables
                      the sharded buffer: actor-affinity inserts, two-level
                      sampling, per-shard batched priority updates)
  --fanout K          sum-tree fan-out (default 64)
  --alpha A --beta B  PER exponents (default 0.6 / 0.4)
  --lr LR             Adam learning rate (default 1e-3)
  --aggregation K     sub-gradients per optimizer step (default 1)
  --n-step N          N-step returns in the default table (default 1)
  --gamma-nstep G     discount for N-step reward folding (default 0.99)
  --tables SPEC       replay-service table layout, comma-separated
                      name=kind[@cap,alpha=A,beta=B,limit=L] entries
                      with kind one of 1step | nstep:N | seq:L
                      (default: one `replay` table following --n-step);
                      limit= attaches a per-table rate limiter in the
                      --rate-limit grammar; learners sample the first
                      table
  --rate-limit R      sample-to-insert limiter default: `legacy`
                      (the --update-interval + actor-lead pacing),
                      `unlimited`, or a samples-per-insert float;
                      applies to the learner-sampled (first) table
                      unless an entry carries its own limit=
  --seed S            PRNG seed
  --stop-at-reward R  early-stop at mean return R
  --log-every SECS    progress line interval (default 5)
  --curve-out FILE    write training curve CSV
  --eps-decay N       epsilon decay steps (DQN-family)
  --action-noise S    exploration noise std (DDPG/TD3)
  --save-checkpoint F write final weights (params::Checkpoint format)
  --save-state DIR    write the unified run state (weights.bin +
                      replay_state.bin: buffers, priorities, table
                      stats, limiter counters) at the end of the run
  --restore-state DIR resume from a previously saved run state
  --checkpoint-every S
                      also snapshot the run state every S seconds
                      during training (atomic; requires --save-state)
  --remote PATH       use an external `pal serve` process at this Unix
                      socket as the replay front-end: actors and
                      learners connect as clients, and the table /
                      buffer / rate-limit flags belong to the server
  --remote-batch N    client-side append batching on a remote run:
                      each actor ships N steps per Append RPC
                      (default 16; 1 = one RPC per step). Samplers
                      always pipeline one batch in flight.

SERVE OPTIONS (same table/buffer flags as train, plus):
  --socket PATH       Unix-domain socket to listen on (required)
  --obs-dim N --act-dim N
                      transition dims of the served tables (must match
                      the connecting run's model; default 4 / 2)
  --restore-state DIR load replay_state.bin from DIR before serving
  --save-state DIR    write replay_state.bin to DIR on clean shutdown
                      (a client's Shutdown RPC)

  `state-smoke` is the CI durability gate: `--phase collect` drives a
  short synthetic writer/sampler run and saves its state; `--phase
  resume` restores into a fresh service and fails unless buffer sizes,
  priority mass and limiter counters all match the snapshot.

  `remote-smoke` is the CI gate for the socket front-end: against a
  freshly started `pal serve` it drives a deterministic writer/sampler
  phase both remotely and in-process and fails unless the two
  checkpoints are byte-identical, then soaks the server with concurrent
  writer/sampler clients and verifies exact sample-to-insert accounting
  over the Stats RPC before asking the server to shut down.
"
    );
    std::process::exit(2)
}

/// Apply the flags shared by `train` (local tables) and `serve` (the
/// same table layout, built in the serving process): buffer kind and
/// geometry, table specs, warmup and rate limiting.
fn apply_service_flags(cfg: &mut TrainConfig, a: &Args) -> Result<()> {
    cfg.warmup_steps = a.parse_or("warmup", cfg.warmup_steps)?;
    cfg.update_interval = a.parse_or("update-interval", cfg.update_interval)?;
    cfg.buffer = BufferKind::parse(&a.str_or("buffer", "pal"))?;
    cfg.buffer_capacity = a.parse_or("capacity", cfg.buffer_capacity)?;
    cfg.shards = a.parse_or("shards", cfg.shards)?;
    cfg.fanout = a.parse_or("fanout", cfg.fanout)?;
    cfg.alpha = a.parse_or("alpha", cfg.alpha)?;
    cfg.beta = a.parse_or("beta", cfg.beta)?;
    cfg.n_step = a.parse_or("n-step", cfg.n_step)?;
    if cfg.n_step == 0 {
        bail!("--n-step must be >= 1");
    }
    cfg.gamma_nstep = a.parse_or("gamma-nstep", cfg.gamma_nstep)?;
    if let Some(spec) = a.get("tables") {
        // Entry-aware splitting: `TableSpec::parse_list` keeps
        // `@alpha=..,beta=..` options attached to their entry.
        cfg.tables = TableSpec::parse_list(spec, cfg.gamma_nstep)?;
    }
    if let Some(r) = a.get("rate-limit") {
        cfg.rate_limit = RateLimitSpec::parse(r)?;
    }
    Ok(())
}

fn train_config_from(a: &Args) -> Result<TrainConfig> {
    a.check_known(TRAIN_FLAGS)?;
    let algo = a.get("algo").ok_or_else(|| anyhow!("--algo required"))?;
    let env = a.get("env").ok_or_else(|| anyhow!("--env required"))?;
    let mut cfg = TrainConfig::new(algo, env);
    cfg.artifact_dir = a.str_or("artifacts", "artifacts").into();
    cfg.actors = a.parse_or("actors", cfg.actors)?;
    cfg.learners = a.parse_or("learners", cfg.learners)?;
    cfg.total_env_steps = a.parse_or("steps", cfg.total_env_steps)?;
    apply_service_flags(&mut cfg, a)?;
    cfg.lr = a.parse_or("lr", cfg.lr)?;
    cfg.grad_clip = a.parse_or("grad-clip", cfg.grad_clip)?;
    cfg.aggregation = a.parse_or("aggregation", cfg.aggregation)?;
    cfg.remote_batch = a.parse_or("remote-batch", cfg.remote_batch)?;
    if cfg.remote_batch == 0 {
        bail!("--remote-batch must be >= 1");
    }
    if let Some(path) = a.get("remote") {
        cfg.remote = Some(path.into());
        // The tables live in the serving process: local table/buffer/
        // limiter flags do nothing on a remote run, and silently
        // ignoring them would let users believe they applied.
        let server_side: &[&str] = &[
            "tables", "capacity", "shards", "fanout", "alpha", "beta", "warmup",
            "rate-limit", "buffer", "n-step", "gamma-nstep",
        ];
        let ignored: Vec<&str> = server_side.iter().copied().filter(|f| a.has(f)).collect();
        if !ignored.is_empty() {
            eprintln!(
                "[pal] WARNING: --remote uses the server's table configuration; \
                 ignoring local flags {ignored:?} (set them on `pal serve`)"
            );
        }
    } else if a.has("remote-batch") {
        eprintln!("[pal] WARNING: --remote-batch only applies to --remote runs; ignored");
    }
    if let Some(dir) = a.get("save-state") {
        cfg.save_state = Some(dir.into());
    }
    if let Some(dir) = a.get("restore-state") {
        cfg.restore_state = Some(dir.into());
    }
    cfg.checkpoint_every_secs = a.parse_or("checkpoint-every", cfg.checkpoint_every_secs)?;
    if cfg.checkpoint_every_secs > 0.0 && cfg.save_state.is_none() {
        bail!("--checkpoint-every requires --save-state DIR");
    }
    cfg.seed = a.parse_or("seed", cfg.seed)?;
    cfg.exploration.eps_decay_steps = a.parse_or("eps-decay", cfg.exploration.eps_decay_steps)?;
    cfg.exploration.action_noise = a.parse_or("action-noise", cfg.exploration.action_noise)?;
    if let Some(r) = a.get("stop-at-reward") {
        cfg.stop_at_reward = Some(r.parse().map_err(|_| anyhow!("bad --stop-at-reward"))?);
    }
    cfg.log_every_secs = a.parse_or("log-every", 5.0)?;
    Ok(cfg)
}

fn cmd_train(a: &Args) -> Result<()> {
    let cfg = train_config_from(a)?;
    eprintln!(
        "[pal] training {} on {} — {} actors, {} learners, buffer={:?}",
        cfg.algo, cfg.env, cfg.actors, cfg.learners, cfg.buffer
    );
    let report = train(&cfg)?;
    println!(
        "done: {} env steps, {} learn steps, {} episodes in {:.1}s \
         ({:.0} env/s, {:.0} learn/s), mean return {:.2}{}",
        report.env_steps,
        report.learn_steps,
        report.episodes,
        report.elapsed_secs,
        report.env_steps_per_sec,
        report.learn_steps_per_sec,
        report.final_mean_return,
        if report.reached_target { " [target reached]" } else { "" },
    );
    for (name, s) in &report.table_stats {
        println!(
            "table {name}: {} inserts, {} batches ({} items), {} priority updates, \
             stalls insert/sample = {}/{}",
            s.inserts,
            s.sample_batches,
            s.sampled_items,
            s.priority_updates,
            s.insert_stalls,
            s.sample_stalls,
        );
    }
    if let Some(path) = a.get("save-checkpoint") {
        pal_rl::params::Checkpoint {
            online: report.final_weights.clone(),
            target: report.final_target_weights.clone(),
            opt_steps: report.opt_steps as u64,
        }
        .save(path)?;
        eprintln!("[pal] checkpoint written to {path}");
    }
    if let Some(path) = a.get("curve-out") {
        let mut csv = String::from("wall_secs,env_steps,learn_steps,episode_return,loss_ema\n");
        for p in &report.curve {
            csv.push_str(&format!(
                "{:.3},{},{},{},{}\n",
                p.wall_secs, p.env_steps, p.learn_steps, p.episode_return, p.loss_ema
            ));
        }
        std::fs::write(path, csv)?;
        eprintln!("[pal] curve written to {path}");
    }
    Ok(())
}

fn cmd_envs() {
    println!("built-in environments:");
    for e in ENV_NAMES {
        let env = pal_rl::env::make_env(e).unwrap();
        let spec = env.spec();
        println!(
            "  {:28} obs_dim={:2} actions={:?} horizon={}",
            spec.name, spec.obs_dim, spec.action_space, spec.max_episode_steps
        );
    }
}

fn cmd_info(a: &Args) -> Result<()> {
    let dir = a.str_or("artifacts", "artifacts");
    let m = Manifest::load(&dir)?;
    println!("manifest at {dir}: {} artifacts", m.artifacts.len());
    for info in m.artifacts.values() {
        println!(
            "  {:32} params={:7} graphs=[{}]",
            info.id,
            info.total_param_size,
            info.graphs.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_buffer_bench(a: &Args) -> Result<()> {
    use pal_rl::replay::*;
    use pal_rl::util::rng::Rng;
    use std::sync::Arc;
    let capacity: usize = a.parse_or("capacity", 100_000)?;
    let fanout: usize = a.parse_or("fanout", 64)?;
    let shards: usize = a.parse_or("shards", 1)?;
    let threads: usize = a.parse_or("threads", 4)?;
    let ops: usize = a.parse_or("ops", 100_000)?;
    let cfg = PrioritizedConfig {
        capacity,
        obs_dim: 8,
        act_dim: 2,
        fanout,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards,
    };
    let buf: Arc<dyn ReplayBuffer> = if shards > 1 {
        Arc::new(ShardedPrioritizedReplay::new(cfg))
    } else {
        Arc::new(PrioritizedReplay::new(cfg))
    };
    let t = Transition {
        obs: vec![0.5; 8],
        action: vec![0.1; 2],
        next_obs: vec![0.6; 8],
        reward: 1.0,
        done: false,
    };
    let prefill = capacity.min(10_000);
    for _ in 0..prefill {
        buf.insert(&t);
    }
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for tid in 0..threads {
            let buf = Arc::clone(&buf);
            let tr = t.clone();
            s.spawn(move || {
                let mut rng = Rng::new(tid as u64);
                let mut out = SampleBatch::default();
                for i in 0..ops / threads {
                    match i % 3 {
                        0 => buf.insert_from(tid, &tr),
                        1 => {
                            buf.sample(32, &mut rng, &mut out);
                        }
                        _ => {
                            // Feed back TDs for the last sampled batch
                            // (keeps updates spread across shards the
                            // way a real learner does).
                            if !out.indices.is_empty() {
                                let idx = out.indices.clone();
                                let tds: Vec<f32> =
                                    idx.iter().map(|_| rng.f32() * 2.0).collect();
                                buf.update_priorities(&idx, &tds);
                            }
                        }
                    }
                }
            });
        }
    });
    let dt = t0.elapsed();
    println!(
        "{} ops across {threads} threads in {:.3}s = {:.0} ops/s \
         (capacity={capacity}, K={fanout}, S={shards}, buffer={})",
        ops,
        dt.as_secs_f64(),
        ops as f64 / dt.as_secs_f64(),
        buf.name(),
    );
    Ok(())
}

const STATE_SMOKE_FLAGS: &[&str] = &["dir", "phase", "items", "capacity", "shards"];
const SMOKE_OBS: usize = 4;
const SMOKE_ACT: usize = 2;

/// The run shape the checkpoint smoke drives: a sharded prioritized
/// learner table under a σ=1 ratio limiter plus a free-running N-step
/// auxiliary table — the config both phases must build identically.
fn smoke_config(a: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::PalKary;
    cfg.buffer_capacity = a.parse_or("capacity", 4_096)?;
    cfg.shards = a.parse_or("shards", 4)?;
    cfg.warmup_steps = 64;
    cfg.rate_limit = RateLimitSpec::SamplesPerInsert(1.0);
    cfg.tables = vec![
        TableSpec {
            name: "replay".into(),
            kind: ItemKind::OneStep,
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
        },
        TableSpec {
            name: "aux".into(),
            kind: ItemKind::NStep { n: 3, gamma: cfg.gamma_nstep },
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
        },
    ];
    Ok(cfg)
}

/// Drive `items` synthetic env steps through the service with 2 writer
/// threads + 1 sampler thread (the learner hot loop with the PJRT
/// compute stripped away), exactly like a miniature train run.
fn smoke_traffic(service: &ReplayService, items: usize) {
    use std::sync::atomic::{AtomicBool, Ordering};
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for actor in 0..2usize {
            let mut writer = service.writer(actor);
            handles.push(s.spawn(move || {
                for i in 0..items / 2 {
                    while writer.throttled() {
                        std::thread::yield_now();
                    }
                    writer.append(WriterStep {
                        obs: vec![i as f32; SMOKE_OBS],
                        action: vec![0.1; SMOKE_ACT],
                        next_obs: vec![i as f32 + 1.0; SMOKE_OBS],
                        reward: 1.0,
                        done: i % 32 == 31,
                        truncated: false,
                    });
                }
            }));
        }
        {
            let sampler = service.default_sampler();
            let done = &done;
            s.spawn(move || {
                let mut rng = pal_rl::util::rng::Rng::new(17);
                let mut out = pal_rl::replay::SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    if sampler.try_sample(16, &mut rng, &mut out) == SampleOutcome::Sampled {
                        let idx = out.indices.clone();
                        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
                        sampler.update_priorities(&idx, &tds);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
        for h in handles {
            h.join().expect("writer thread panicked");
        }
        done.store(true, Ordering::Relaxed);
    });
}

/// Checkpoint round-trip smoke (the CI durability gate). `--phase
/// collect` runs synthetic traffic and saves the unified run state;
/// `--phase resume` rebuilds the same service in a NEW process,
/// restores, and asserts element counts, priority mass and limiter
/// counters all equal the snapshotted values, then proves the resumed
/// service still trains (more traffic, ratio bound intact).
fn cmd_state_smoke(a: &Args) -> Result<()> {
    a.check_known(STATE_SMOKE_FLAGS)?;
    let dir: std::path::PathBuf =
        a.get("dir").ok_or_else(|| anyhow!("--dir required"))?.into();
    let items: usize = a.parse_or("items", 2_000)?;
    let cfg = smoke_config(a)?;
    let service = build_service(&cfg, SMOKE_OBS, SMOKE_ACT)?;
    let server = ParameterServer::new(
        vec![0.5; 16],
        AdamConfig::default(),
        TargetSync::None,
        1,
    );
    match a.get("phase") {
        Some("collect") => {
            smoke_traffic(&service, items);
            server.push_gradient(0, 16, &[0.1; 16]);
            save_run_state(&dir, &server, &service)?;
            for t in service.tables() {
                eprintln!("[smoke] saved {}", t.stats_line());
            }
            println!(
                "state-smoke collect OK: {} items saved to {}",
                service.total_len(),
                dir.display()
            );
            Ok(())
        }
        Some("resume") => {
            let state = ServiceState::load(dir.join(STATE_FILE))?;
            restore_run_state(&dir, &server, &service)?;
            for t in service.tables() {
                let ts = state
                    .table(t.name())
                    .ok_or_else(|| anyhow!("table `{}` missing from state", t.name()))?;
                ensure!(
                    t.len() == ts.buffer.len(),
                    "table `{}`: restored {} items, snapshot has {}",
                    t.name(),
                    t.len(),
                    ts.buffer.len()
                );
                ensure!(
                    t.stats_snapshot() == ts.stats,
                    "table `{}`: restored counters {:?} != snapshot {:?}",
                    t.name(),
                    t.stats_snapshot(),
                    ts.stats
                );
            }
            // Priority mass: re-capture the restored service and compare
            // per-table priority sums against the file.
            let recap = ServiceState::capture(&service)?;
            for ts in &state.tables {
                let got = recap.table(&ts.name).unwrap().buffer.total_priority();
                let want = ts.buffer.total_priority();
                ensure!(
                    (got - want).abs() <= want.abs().max(1.0) * 1e-3,
                    "table `{}`: restored priority mass {got} != snapshot {want}",
                    ts.name
                );
            }
            ensure!(server.opt_steps() == 1, "optimizer steps not restored");
            // The resumed service keeps working: more traffic, and the
            // sample-to-insert ratio bound holds over the COMBINED
            // (restored + new) counters.
            let before = service.default_table().stats_snapshot();
            smoke_traffic(&service, 512);
            let after = service.default_table().stats_snapshot();
            ensure!(after.inserts > before.inserts, "resumed run inserted nothing");
            ensure!(
                after.sample_batches as f64 <= after.inserts as f64 + 1e-9,
                "ratio bound violated after resume: {} batches vs {} inserts",
                after.sample_batches,
                after.inserts
            );
            println!(
                "state-smoke resume OK: {} items, priority mass and limiter counters match; \
                 +{} inserts after resume",
                state.total_len(),
                after.inserts - before.inserts
            );
            Ok(())
        }
        other => bail!("--phase must be `collect` or `resume`, got {other:?}"),
    }
}

const SERVE_FLAGS: &[&str] = &[
    "socket", "buffer", "capacity", "shards", "fanout", "alpha", "beta",
    "warmup", "update-interval", "n-step", "gamma-nstep", "tables",
    "rate-limit", "obs-dim", "act-dim", "seed", "restore-state", "save-state",
];

/// `pal serve`: build a replay service from the same table/buffer flags
/// `train` uses and expose it on a Unix-domain socket, so actors and
/// learners in OTHER processes (`pal train --remote PATH`) share its
/// tables. Runs until a client sends the Shutdown RPC (or the process
/// is killed); a clean shutdown optionally saves the replay state.
fn cmd_serve(a: &Args) -> Result<()> {
    a.check_known(SERVE_FLAGS)?;
    let socket = a
        .get("socket")
        .ok_or_else(|| anyhow!("--socket PATH required"))?
        .to_string();
    let mut cfg = TrainConfig::new("serve", "remote");
    apply_service_flags(&mut cfg, a)?;
    let obs_dim: usize = a.parse_or("obs-dim", 4)?;
    let act_dim: usize = a.parse_or("act-dim", 2)?;
    let seed: u64 = a.parse_or("seed", 0)?;
    let service = Arc::new(build_service(&cfg, obs_dim, act_dim)?);
    if let Some(dir) = a.get("restore-state") {
        let state = ServiceState::load(std::path::Path::new(dir).join(STATE_FILE))?;
        service.restore(&state)?;
        eprintln!(
            "[pal] replay server restored {} items from {dir}",
            service.total_len()
        );
    }
    let server =
        ReplayServer::bind(Arc::clone(&service), &socket, seed)?.expect_dims(obs_dim, act_dim);
    eprintln!(
        "[pal] replay server listening on {socket} — {}",
        service.stats_line()
    );
    server.serve()?;
    if let Some(dir) = a.get("save-state") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        ServiceState::capture(&service)?.save(dir.join(STATE_FILE))?;
        eprintln!(
            "[pal] replay state saved to {} ({} items)",
            dir.display(),
            service.total_len()
        );
    }
    eprintln!("[pal] replay server stopped — {}", service.stats_line());
    Ok(())
}

const REMOTE_SMOKE_FLAGS: &[&str] = &["socket", "items", "capacity", "shards"];

/// Seed of the deterministic phase's sampling RNG — the remote
/// connection's server-side RNG (via Hello) and the in-process twin's
/// local RNG, so the two runs draw identical index sequences.
const REMOTE_SMOKE_SEED: u64 = 0x5EED_50CC;

/// One synthetic env step of the remote smoke's traffic.
fn smoke_step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32; SMOKE_OBS],
        action: vec![0.1; SMOKE_ACT],
        next_obs: vec![i as f32 + 1.0; SMOKE_OBS],
        reward: 1.0,
        done: i % 32 == 31,
        truncated: false,
    }
}

/// Client-side append batch of the smoke's remote writer, and the
/// group size of [`deterministic_drive`] — the two must agree so the
/// batched remote appends land on the server exactly where the
/// in-process twin's writer has inserted them.
const REMOTE_SMOKE_BATCH: usize = 16;

/// Deterministic collect/sample loop over trait-level handles, so the
/// EXACT same call sequence can run against a remote server and an
/// in-process service. Steps go in `chunk`-aligned groups (the remote
/// writer's `--remote-batch`), each group followed by one
/// sample+priority-update round per step past `warmup`, which with the
/// smoke's σ=1 ratio limiter keeps the drift window open — the loop
/// never stalls, so even the stall counters of the two runs stay
/// equal. Returns the number of granted batches.
fn deterministic_drive(
    w: &mut dyn ExperienceWriter,
    s: &mut dyn ExperienceSampler,
    rng: &mut Rng,
    warmup: usize,
    items: usize,
    chunk: usize,
) -> Result<u64> {
    let mut out = SampleBatch::default();
    let mut batches = 0u64;
    let mut start = 0usize;
    while start < items {
        let group = chunk.min(items - start);
        for i in start..start + group {
            ensure!(
                !w.throttled()?,
                "deterministic phase writer unexpectedly throttled at item {i}"
            );
            w.append(smoke_step(i))?;
        }
        // A partial tail group (items not a chunk multiple) still has
        // to land before its samples; a full group already shipped at
        // the batching threshold.
        ensure!(
            w.flush()? == 0,
            "deterministic phase writer stalled flushing at item {start}"
        );
        for i in start..start + group {
            if i < warmup {
                continue;
            }
            match s.try_sample(16, rng, &mut out)? {
                SampleOutcome::Sampled => {
                    batches += 1;
                    let idx = out.indices.clone();
                    // Priorities are a pure function of (round, slot) so
                    // both runs feed identical values.
                    let tds: Vec<f32> = (0..idx.len())
                        .map(|j| ((batches * 31 + j as u64) % 97) as f32 * 0.1 + 0.05)
                        .collect();
                    s.update_priorities(&idx, &tds)?;
                }
                other => bail!("deterministic phase stalled sampling at item {i}: {other:?}"),
            }
        }
        start += group;
    }
    Ok(batches)
}

/// Deterministic pipelined-sampling phase: `rounds` lockstep
/// sample+update rounds with prefetch enabled remotely and a plain
/// in-process sampler locally. With no appends interleaved, the
/// prefetch (drawn right after each update, before the next
/// `try_sample`) sees exactly the state the local sampler sees, so the
/// two stay bit-identical. The trailing in-flight prefetch is drained
/// and mirrored with one extra local draw, keeping the counters — and
/// the checkpoints — equal. Returns `(granted, updated)` batch counts
/// (the drained prefetch is granted but never priority-updated).
fn prefetch_lockstep_drive(
    remote: &mut RemoteSampler,
    local: &pal_rl::service::SamplerHandle,
    local_rng: &mut Rng,
    rounds: usize,
) -> Result<(u64, u64)> {
    let mut unused = Rng::new(7); // remote sampling uses the server-side RNG
    let mut remote_out = SampleBatch::default();
    let mut local_out = SampleBatch::default();
    let mut batches = 0u64;
    for round in 0..rounds {
        let r = remote.try_sample(16, &mut unused, &mut remote_out)?;
        let l = local.try_sample(16, local_rng, &mut local_out);
        ensure!(r == l, "prefetch round {round}: outcomes diverged ({r:?} vs {l:?})");
        ensure!(r == SampleOutcome::Sampled, "prefetch round {round} stalled: {r:?}");
        ensure!(
            remote_out.indices == local_out.indices,
            "prefetch round {round}: sampled indices diverged"
        );
        batches += 1;
        let tds: Vec<f32> = (0..remote_out.indices.len())
            .map(|j| ((round * 17 + j) % 89) as f32 * 0.1 + 0.05)
            .collect();
        remote.update_priorities(&remote_out.indices, &tds)?;
        local.update_priorities(&local_out.indices, &tds);
    }
    let updates = batches;
    // The pipeline's trailing prefetch is a batch the server already
    // granted and counted; mirror it locally so both sides' counters
    // (and therefore their checkpoints) stay identical.
    if let Some(outcome) = remote.drain()? {
        let l = local.try_sample(16, local_rng, &mut local_out);
        ensure!(
            outcome == l,
            "drained prefetch outcome {outcome:?} diverged from local {l:?}"
        );
        if outcome == SampleOutcome::Sampled {
            batches += 1;
        }
    }
    Ok((batches, updates))
}

/// Remote round-trip smoke (the CI gate for the socket front-end), run
/// against a FRESHLY started `pal serve` on the same table layout as
/// `state-smoke` (tools/remote_smoke.sh starts it with matching flags):
///
/// 1. deterministic phase — one BATCHED writer (`--remote-batch`-style
///    chunks) + one seeded sampler drive the server through
///    `RemoteWriter`/`RemoteSampler`, the identical loop drives an
///    in-process twin service;
/// 2. deterministic prefetch phase — a pipelined sampler (one batch in
///    flight behind every priority update) runs lockstep against the
///    twin; after both phases the two checkpoints must be
///    BYTE-identical (items, priorities, stats, limiter counters);
/// 3. concurrent soak — two batched writer clients + one pipelined
///    sampler client hammer the server; every sampled batch must be
///    zero-priority-free and the final Stats must account for every
///    client-side operation exactly (inserts, batches, items,
///    priority updates);
/// 4. Shutdown RPC — the serving process exits cleanly (and writes its
///    `--save-state`, which the script asserts).
fn cmd_remote_smoke(a: &Args) -> Result<()> {
    a.check_known(REMOTE_SMOKE_FLAGS)?;
    let socket = a
        .get("socket")
        .ok_or_else(|| anyhow!("--socket PATH required"))?
        .to_string();
    let items: usize = a.parse_or("items", 2_000)?;
    let cfg = smoke_config(a)?;
    ensure!(
        items >= cfg.warmup_steps * 4,
        "--items {items} too small for warmup {}",
        cfg.warmup_steps
    );

    // The server must be fresh: the deterministic comparison assumes
    // both sides start from empty tables.
    let before = RemoteClient::connect(&socket)?.stats()?;
    ensure!(
        before.iter().all(|t| t.len == 0 && t.stats.inserts == 0),
        "remote-smoke needs a freshly started server (tables already hold data)"
    );
    ensure!(!before.is_empty(), "server reports no tables");

    // Phase 1a: deterministic drive over the wire, appends batched.
    let mut remote_writer = RemoteWriter::connect(&socket, 0)?.with_batch(REMOTE_SMOKE_BATCH);
    let mut remote_sampler = RemoteSampler::connect_default(&socket, REMOTE_SMOKE_SEED)?;
    let mut unused_rng = Rng::new(1); // remote sampling uses the server-side RNG
    let remote_batches = deterministic_drive(
        &mut remote_writer,
        &mut remote_sampler,
        &mut unused_rng,
        cfg.warmup_steps,
        items,
        REMOTE_SMOKE_BATCH,
    )?;

    // Phase 1b: the identical drive against an in-process twin.
    let local = build_service(&cfg, SMOKE_OBS, SMOKE_ACT)?;
    let mut local_writer = local.writer(0);
    let mut local_sampler = local.default_sampler();
    let mut local_rng = Rng::new(REMOTE_SMOKE_SEED);
    let local_batches = deterministic_drive(
        &mut local_writer,
        &mut local_sampler,
        &mut local_rng,
        cfg.warmup_steps,
        items,
        REMOTE_SMOKE_BATCH,
    )?;
    ensure!(
        remote_batches == local_batches,
        "granted batches diverged: remote {remote_batches} vs local {local_batches}"
    );

    // Phase 2: pipelined sampling in lockstep with the twin. A fresh
    // seeded connection on each side; prefetched batches must track
    // the in-process draws exactly.
    let prefetch_seed = REMOTE_SMOKE_SEED ^ 0xA5A5;
    let mut prefetch_sampler =
        RemoteSampler::connect_default(&socket, prefetch_seed)?.with_prefetch(true);
    let mut prefetch_rng = Rng::new(prefetch_seed);
    let (prefetch_batches, prefetch_updates) = prefetch_lockstep_drive(
        &mut prefetch_sampler,
        &local.default_sampler(),
        &mut prefetch_rng,
        32,
    )?;

    // The wire must not change the state: byte-identical checkpoints
    // after batched appends AND pipelined sampling.
    let remote_bytes = RemoteClient::connect(&socket)?.checkpoint_bytes()?;
    let local_bytes = ServiceState::capture(&local)?.encode();
    if remote_bytes != local_bytes {
        let first_diff = remote_bytes
            .iter()
            .zip(&local_bytes)
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| remote_bytes.len().min(local_bytes.len()));
        bail!(
            "remote checkpoint differs from the in-process twin: {} vs {} bytes, \
             first difference at offset {first_diff}",
            remote_bytes.len(),
            local_bytes.len()
        );
    }
    eprintln!(
        "[smoke] deterministic phase OK: {} items (batch {REMOTE_SMOKE_BATCH}), \
         {remote_batches}+{prefetch_batches} batches (plain+prefetch), \
         checkpoints byte-identical ({} bytes)",
        items,
        remote_bytes.len()
    );
    // Quiesce deterministic connections so the final Shutdown drains fast.
    drop(remote_writer);
    drop(remote_sampler);
    drop(prefetch_sampler);

    // Phase 3: concurrent soak through separate client connections —
    // batched writers, pipelined sampler.
    let soak_each = (items / 4).max(64);
    let done = std::sync::atomic::AtomicBool::new(false);
    let soak_batches = std::sync::atomic::AtomicUsize::new(0);
    let soak_updates = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| -> Result<()> {
        let mut writers = Vec::new();
        for actor in 1..3usize {
            let socket = socket.clone();
            writers.push(s.spawn(move || -> Result<()> {
                let mut w =
                    RemoteWriter::connect(&socket, actor as u64)?.with_batch(REMOTE_SMOKE_BATCH);
                // Bounded waits so a dead sampler fails the smoke
                // instead of hanging CI.
                let wait_admitted = |w: &mut RemoteWriter| -> Result<()> {
                    let mut spins = 0u32;
                    while w.throttled()? {
                        spins += 1;
                        ensure!(spins < 60_000, "soak writer stalled >60s (sampler dead?)");
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Ok(())
                };
                for i in 0..soak_each {
                    wait_admitted(&mut w)?;
                    w.append(smoke_step(actor * 1_000_000 + i))?;
                }
                // Drain: the sub-batch tail AND any steps the limiter
                // stalled must still land before the tally.
                let mut spins = 0u32;
                while w.flush()? > 0 {
                    spins += 1;
                    ensure!(spins < 60_000, "soak writer could not drain (sampler dead?)");
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Ok(())
            }));
        }
        let sampler_handle = {
            let socket = socket.clone();
            let done = &done;
            let soak_batches = &soak_batches;
            let soak_updates = &soak_updates;
            s.spawn(move || -> Result<()> {
                let mut sampler = RemoteSampler::connect_default(&socket, 99)?.with_prefetch(true);
                let mut rng = Rng::new(99);
                let mut out = SampleBatch::default();
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    match sampler.try_sample(16, &mut rng, &mut out)? {
                        SampleOutcome::Sampled => {
                            ensure!(
                                out.priorities.iter().all(|&p| p > 0.0),
                                "sampled a zero-priority item over the wire"
                            );
                            soak_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0 + 0.01).collect();
                            sampler.update_priorities(&idx, &tds)?;
                            soak_updates.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        _ => std::thread::yield_now(),
                    }
                }
                // The pipeline's trailing prefetch is a granted batch
                // the server counted; tally it so the Stats accounting
                // below stays exact.
                if sampler.drain()? == Some(SampleOutcome::Sampled) {
                    soak_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Ok(())
            })
        };
        // Collect every outcome BEFORE propagating any error: an early
        // return would leave `done` unset and the scope joining a
        // sampler that never exits.
        let writer_results: Vec<_> = writers.into_iter().map(|h| h.join()).collect();
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        let sampler_result = sampler_handle.join();
        for r in writer_results {
            r.map_err(|_| anyhow!("soak writer panicked"))??;
        }
        sampler_result.map_err(|_| anyhow!("soak sampler panicked"))??;
        Ok(())
    })?;
    let soak_batches = soak_batches.load(std::sync::atomic::Ordering::Relaxed) as u64;
    let soak_updates = soak_updates.load(std::sync::atomic::Ordering::Relaxed) as u64;

    // Exact accounting across the wire, against the final Stats.
    let stats = RemoteClient::connect(&socket)?.stats()?;
    ensure!(!stats.is_empty(), "server reports no tables after the soak");
    let total_inserts = items + 2 * soak_each;
    let total_batches = remote_batches + prefetch_batches + soak_batches;
    // Drained trailing prefetches are granted batches that never got a
    // priority update, so updates are tracked separately.
    let total_updates = remote_batches + prefetch_updates + soak_updates;
    for t in &stats {
        ensure!(t.len > 0, "table `{}` is empty after the smoke", t.name);
        ensure!(
            t.len <= t.capacity,
            "table `{}` overflows its capacity",
            t.name
        );
        // The 1-step learner table gets exactly one item per appended
        // step. N-step tables legitimately emit up to n−1 fewer items
        // per writer whose final episode never terminated (the partial
        // window tail is only flushed at a boundary).
        let slack = if t.name == stats[0].name { 0 } else { 3 * 3 };
        ensure!(
            t.stats.inserts <= total_inserts && t.stats.inserts + slack >= total_inserts,
            "table `{}`: {} inserts recorded, clients performed {total_inserts}",
            t.name,
            t.stats.inserts
        );
    }
    let replay = &stats[0];
    ensure!(
        replay.stats.sample_batches as u64 == total_batches,
        "table `{}`: {} batches recorded, clients drew {total_batches}",
        replay.name,
        replay.stats.sample_batches
    );
    ensure!(
        replay.stats.sampled_items as u64 == 16 * total_batches,
        "sampled-items accounting off: {} != 16·{total_batches}",
        replay.stats.sampled_items
    );
    ensure!(
        replay.stats.priority_updates as u64 == 16 * total_updates,
        "priority-update accounting off: {} != 16·{total_updates}",
        replay.stats.priority_updates
    );
    // The σ=1 ratio bound holds over the combined phases.
    ensure!(
        replay.stats.sample_batches <= replay.stats.inserts,
        "ratio bound violated: {} batches vs {} inserts",
        replay.stats.sample_batches,
        replay.stats.inserts
    );
    eprintln!(
        "[smoke] soak OK: +{} inserts, {soak_batches} batches, stalls i/s = {}/{}",
        2 * soak_each,
        replay.stats.insert_stalls,
        replay.stats.sample_stalls
    );

    RemoteClient::connect(&socket)?.shutdown()?;
    println!(
        "remote-smoke OK: {total_inserts} inserts, {total_batches} batches, \
         byte-identical checkpoint, exact accounting over the wire"
    );
    Ok(())
}

fn cmd_dse(a: &Args) -> Result<()> {
    let cores: usize = a.parse_or("cores", 8)?;
    let ratio: f64 = a.parse_or("update-interval", 1.0)?;
    let algo = a.str_or("algo", "dqn");
    let env = a.str_or("env", "CartPole-v1");
    let mut profile = dse::CostProfile::representative(&algo, &env);
    // Replay-service rate limiter in the modeled pipeline (σ samples
    // per insert; 0 = no limiter).
    profile.samples_per_insert = a.parse_or("rate-limit", 0.0)?;
    let plan = dse::explore(&profile, cores, ratio);
    println!("{}", dse::render_curves(&profile, cores));
    println!(
        "chosen split for M={cores}, ratio={ratio}: {} actors + {} learners \
         (collect {:.0}/s vs consume {:.0}/s)",
        plan.actors, plan.learners, plan.collect_throughput, plan.consume_throughput
    );
    if profile.samples_per_insert > 0.0 {
        let (actor_stall, learner_stall) =
            profile.limiter_stalls(plan.actors, plan.learners, cores);
        println!(
            "rate limiter σ={}: stall terms at this split — actors {:.1}%, \
             learners {:.1}% of free-run throughput",
            profile.samples_per_insert,
            actor_stall * 100.0,
            learner_stall * 100.0,
        );
    }
    // Replay-shard dimension of the design space.
    let candidates = a.usize_list("shards", &[1, 2, 4, 8, 16])?;
    let sweep = profile.shard_sweep(cores, ratio, &candidates);
    println!("\nshard sweep (best balanced throughput per S):");
    for &(s, tput) in &sweep {
        println!("  S={s:2}  {tput:10.0} steps/s");
    }
    let (best_s, best_t) = dse::CostProfile::pick_best_shards(&sweep);
    println!("planner's shard choice: S={best_s} ({best_t:.0} steps/s)");
    Ok(())
}

fn main() -> Result<()> {
    let a = Args::from_env()?;
    let cmd = a.positional.first().map(String::as_str);
    match cmd {
        Some("train") => cmd_train(&a),
        Some("serve") => cmd_serve(&a),
        Some("envs") => {
            cmd_envs();
            Ok(())
        }
        Some("info") => cmd_info(&a),
        Some("buffer-bench") => cmd_buffer_bench(&a),
        Some("state-smoke") => cmd_state_smoke(&a),
        Some("remote-smoke") => cmd_remote_smoke(&a),
        Some("dse") => cmd_dse(&a),
        Some(other) => bail!("unknown subcommand `{other}` (try `pal` for usage)"),
        None => usage(),
    }
}
