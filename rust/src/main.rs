//! `pal` — launcher for the Parallel Actors and Learners framework.
//!
//! Subcommands:
//!   train         run one training session (the paper's Fig 7 pipeline)
//!   serve         expose a replay service on a Unix or TCP socket (`--remote` target)
//!   dse           design-space exploration: pick actor/learner core split
//!   buffer-bench  quick replay-buffer micro-benchmark
//!   envs          list built-in environments
//!   info          show manifest contents

use anyhow::{anyhow, bail, ensure, Result};
use pal_rl::coordinator::{
    build_service, restore_run_state, save_run_state, train, BufferKind, TrainConfig,
};
use pal_rl::dse;
use pal_rl::env::ENV_NAMES;
use pal_rl::params::{AdamConfig, ParameterServer, TargetSync};
use pal_rl::remote::{
    parse_endpoint_list, BackoffPolicy, ChaosConfig, ChaosProxy, ConnectionPolicy, Endpoint,
    HealthState, MeshSampler, MeshWriter, RemoteClient, RemoteSampler, RemoteWriter, ReplayServer,
};
use pal_rl::remote::TableInfo;
use pal_rl::replay::{RemoverSpec, SampleBatch};
use pal_rl::runtime::Manifest;
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimitSpec, ReplayService, SampleOutcome,
    ServiceState, TableSpec, WriterStep, STATE_FILE,
};
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::Arc;

const TRAIN_FLAGS: &[&str] = &[
    "algo", "env", "artifacts", "actors", "learners", "steps", "warmup",
    "update-interval", "buffer", "capacity", "shards", "fanout", "alpha",
    "beta", "lr", "grad-clip", "aggregation", "seed", "stop-at-reward",
    "log-every", "curve-out", "eps-decay", "action-noise", "save-checkpoint",
    "n-step", "gamma-nstep", "tables", "rate-limit", "remove", "save-state",
    "restore-state", "checkpoint-every", "remote", "remote-batch",
    "rpc-timeout", "reconnect-deadline", "spill-cap", "mass-ttl",
];

fn usage() -> ! {
    eprintln!(
        "pal — Parallel Actors and Learners

USAGE:
  pal train --algo <dqn|ddqn|ddpg|td3|sac> --env <ENV> [options]
  pal serve (--socket PATH | --tcp HOST:PORT) [--obs-dim N] [--act-dim N] [table/buffer options]
  pal dse   --algo <A> --env <E> [--cores M] [--update-interval R] [--shards 1,2,4,8,16] [--rate-limit S]
  pal buffer-bench [--capacity N] [--fanout K] [--shards S] [--threads T] [--ops N]
  pal state-smoke --dir DIR --phase <collect|resume> [--items N] [--capacity N] [--shards S]
  pal remote-smoke --socket PATH [--items N] [--capacity N] [--shards S]
  pal tenant-smoke --socket PATH
  pal mesh-smoke --endpoints EP1,EP2[,..] [--items N] [--capacity N] [--shards S]
  pal chaos-smoke [--dir DIR] [--seed S] [--steps-per-writer N] [--batches-per-sampler N] [--tcp]
  pal mesh-chaos-smoke [--dir DIR] [--items N] [--capacity N] [--shards S]
  pal drain --endpoint EP [--to EP1[,EP2..]] [--chunk BYTES]
  pal envs
  pal info  [--artifacts DIR]

TRAIN OPTIONS:
  --actors N          parallel actors (default 1)
  --learners N        parallel learners (default 1)
  --steps N           total env steps (default 20000)
  --warmup N          env steps before learning starts (default 1000)
  --update-interval R env-steps per learn-step ratio (default 1.0)
  --buffer KIND       pal | baseline | uniform | emulated-python | emulated-binding
  --capacity N        replay capacity (default 100000)
  --shards S          replay shards, pal buffer only (default 1; >1 enables
                      the sharded buffer: actor-affinity inserts, two-level
                      sampling, per-shard batched priority updates)
  --fanout K          sum-tree fan-out (default 64)
  --alpha A --beta B  PER exponents (default 0.6 / 0.4)
  --lr LR             Adam learning rate (default 1e-3)
  --aggregation K     sub-gradients per optimizer step (default 1)
  --n-step N          N-step returns in the default table (default 1)
  --gamma-nstep G     discount for N-step reward folding (default 0.99)
  --tables SPEC       replay-service table layout, comma-separated
                      name=kind[@cap,alpha=A,beta=B,limit=L,remove=P]
                      entries with kind one of 1step | nstep:N | seq:L
                      (default: one `replay` table following --n-step);
                      limit= attaches a per-table rate limiter in the
                      --rate-limit grammar; remove= overrides --remove
                      for that table; learners sample the first table
  --remove POLICY     run-default eviction policy when a full table
                      admits an insert: fifo (default) | lifo |
                      lowest (least-priority item) | max_sampled:N
                      (oldest item sampled at least N times; falls
                      back to FIFO while none qualifies)
  --rate-limit R      sample-to-insert limiter default: `legacy`
                      (the --update-interval + actor-lead pacing),
                      `unlimited`, or a samples-per-insert float;
                      applies to the learner-sampled (first) table
                      unless an entry carries its own limit=
  --seed S            PRNG seed
  --stop-at-reward R  early-stop at mean return R
  --log-every SECS    progress line interval (default 5)
  --curve-out FILE    write training curve CSV
  --eps-decay N       epsilon decay steps (DQN-family)
  --action-noise S    exploration noise std (DDPG/TD3)
  --save-checkpoint F write final weights (params::Checkpoint format)
  --save-state DIR    write the unified run state (weights.bin +
                      replay_state.bin: buffers, priorities, table
                      stats, limiter counters) at the end of the run
  --restore-state DIR resume from a previously saved run state
  --checkpoint-every S
                      also snapshot the run state every S seconds
                      during training (atomic; requires --save-state)
  --remote LIST       use external `pal serve` processes as the replay
                      front-end. LIST is comma-separated endpoints —
                      `uds://PATH` (or a bare socket path) and
                      `tcp://HOST:PORT`. One endpoint connects actors
                      and learners as clients of that server; two or
                      more form a replay mesh (actors spread over
                      servers by affinity, learners sample across them
                      by priority mass). The table / buffer /
                      rate-limit flags belong to the servers
  --remote-batch N    client-side append batching on a remote run:
                      each actor ships N steps per Append RPC
                      (default 16; 1 = one RPC per step). Samplers
                      always pipeline one batch in flight.
  --rpc-timeout SECS  per-RPC socket timeout on a remote run (default
                      120); a silent RPC past this counts as a dead
                      connection and triggers a supervised reconnect
  --reconnect-deadline SECS
                      how long a remote connection keeps retrying
                      (exponential backoff, seeded jitter) before the
                      worker gives up on an outage (default 30)
  --spill-cap N       max steps a remote writer queues locally while
                      the server is unreachable (default 65536); past
                      the cap the oldest steps drop, counted in the
                      server's steps_dropped stat after the link heals
  --mass-ttl MS       mesh only: how long learners may reuse a cached
                      set of per-server mass adverts before re-probing
                      (default 5 ms, also bounded to 64 draws; 0 =
                      probe before every draw, the exact-lockstep
                      mode mesh-smoke verifies). The probe doubles as
                      the health check that drives failover

SERVE OPTIONS (same table/buffer flags as train, plus):
  --socket PATH       Unix-domain socket to listen on
  --tcp HOST:PORT     TCP address to listen on instead (`:0` binds an
                      ephemeral port; the resolved address is printed
                      on the `listening on` line). Exactly one of
                      --socket / --tcp is required
  --obs-dim N --act-dim N
                      transition dims of the served tables (must match
                      the connecting run's model; default 4 / 2)
  --restore-state DIR load replay_state.bin from DIR before serving
  --save-state DIR    write replay_state.bin to DIR on clean shutdown
                      (a client's Shutdown RPC, SIGINT or SIGTERM)
  --drain-deadline SECS
                      max wait for in-flight connections to finish
                      after a shutdown request (default 5)
  --writer-budget N   per-connection insert budget: each writer
                      session may append at most N steps for the life
                      of the server (0 = unlimited, the default).
                      Exhausted writers get retriable would-stall
                      replies, not errors
  --max-writers-per-table N
                      cap concurrent writer sessions per table
                      (0 = unlimited, the default); a writer claims
                      every table its hello ACL names, all or nothing
  --drain-to LIST     default handoff peers for a `Drain` RPC that
                      names none: when this server is told to leave
                      the mesh (`pal drain`), it refuses new sessions,
                      streams its tables to the first reachable peer
                      in LIST over the chunked transfer stream, and
                      exits cleanly

  `drain` tells a running `pal serve` to leave the mesh: the server
  stops admitting appends and new sessions, hands every table (rows,
  priorities, drop counters) to the first reachable peer — `--to`
  overrides the server's `--drain-to` list — and shuts down. Mesh
  writers fail over to surviving servers; mesh samplers renormalize
  their mass draw away from it.

  `state-smoke` is the CI durability gate: `--phase collect` drives a
  short synthetic writer/sampler run and saves its state; `--phase
  resume` restores into a fresh service and fails unless buffer sizes,
  priority mass and limiter counters all match the snapshot.

  `remote-smoke` is the CI gate for the socket front-end: against a
  freshly started `pal serve` it drives a deterministic writer/sampler
  phase both remotely and in-process and fails unless the two
  checkpoints are byte-identical, then soaks the server with concurrent
  writer/sampler clients and verifies exact sample-to-insert accounting
  over the Stats RPC before asking the server to shut down.

  `tenant-smoke` is the CI gate for multi-tenant serving: against a
  `pal serve` started with per-writer budgets, a writers-per-table cap
  and a legacy (PALSTAT1) checkpoint restored, it connects tenants
  with disjoint table ACLs and fails unless the restored rows are
  visible, quota rejections surface as retriable would-stall replies
  with exact partial-consume accounting, ACL violations are rejected
  without killing the connection, and the final Stats show exact
  per-tenant insert and eviction counts.

  `mesh-smoke` is the CI gate for the cross-host replay mesh: against
  N freshly started servers (any mix of transports) it drives a seeded
  mesh run — affinity-routed appends, mass-proportional two-level
  sampling, priority feedback — in lockstep with N in-process twin
  services, and fails unless every sampled batch and every per-server
  checkpoint (moved over the chunked transfer stream) is byte-identical
  to its twin and the per-server Stats account for every client
  operation exactly.

  `chaos-smoke` is the CI fault-tolerance gate (restart drill): it
  starts its own replay server behind a seeded fault-injecting proxy
  (delays, shredded writes, connection resets), soaks it with
  concurrent writers and samplers, hard-kills the server mid-run and
  restarts it from a checkpoint, and fails unless every step is
  accounted for exactly once and the final checkpoint is byte-identical
  to an unfaulted in-process twin — including a writer pushed past its
  --spill-cap, whose dropped steps must land in steps_dropped.

  `mesh-chaos-smoke` is the CI elasticity gate (kill-and-rejoin
  drill): it starts a 3-server replay mesh in-process, soaks it with
  affinity writers and a mass-proportional sampler, hard-kills one
  server mid-run (survivors must keep sampling, the stranded writer
  must fail over carrying its spilled steps), restarts the victim from
  its checkpoint (the sampler must mark it Up again and resume drawing
  from it, the writer must fail back home), then live-drains another
  server into a peer — and fails unless the per-server Stats deltas
  account for every append, sampled batch and priority update
  mesh-wide, exactly.
"
    );
    std::process::exit(2)
}

/// Apply the flags shared by `train` (local tables) and `serve` (the
/// same table layout, built in the serving process): buffer kind and
/// geometry, table specs, warmup and rate limiting.
fn apply_service_flags(cfg: &mut TrainConfig, a: &Args) -> Result<()> {
    cfg.warmup_steps = a.parse_or("warmup", cfg.warmup_steps)?;
    cfg.update_interval = a.parse_or("update-interval", cfg.update_interval)?;
    cfg.buffer = BufferKind::parse(&a.str_or("buffer", "pal"))?;
    cfg.buffer_capacity = a.parse_or("capacity", cfg.buffer_capacity)?;
    cfg.shards = a.parse_or("shards", cfg.shards)?;
    cfg.fanout = a.parse_or("fanout", cfg.fanout)?;
    cfg.alpha = a.parse_or("alpha", cfg.alpha)?;
    cfg.beta = a.parse_or("beta", cfg.beta)?;
    cfg.n_step = a.parse_or("n-step", cfg.n_step)?;
    if cfg.n_step == 0 {
        bail!("--n-step must be >= 1");
    }
    cfg.gamma_nstep = a.parse_or("gamma-nstep", cfg.gamma_nstep)?;
    if let Some(spec) = a.get("tables") {
        // Entry-aware splitting: `TableSpec::parse_list` keeps
        // `@alpha=..,beta=..` options attached to their entry.
        cfg.tables = TableSpec::parse_list(spec, cfg.gamma_nstep)?;
    }
    if let Some(r) = a.get("rate-limit") {
        cfg.rate_limit = RateLimitSpec::parse(r)?;
    }
    if let Some(r) = a.get("remove") {
        cfg.remove = RemoverSpec::parse(r)?;
    }
    Ok(())
}

fn train_config_from(a: &Args) -> Result<TrainConfig> {
    a.check_known(TRAIN_FLAGS)?;
    let algo = a.get("algo").ok_or_else(|| anyhow!("--algo required"))?;
    let env = a.get("env").ok_or_else(|| anyhow!("--env required"))?;
    let mut cfg = TrainConfig::new(algo, env);
    cfg.artifact_dir = a.str_or("artifacts", "artifacts").into();
    cfg.actors = a.parse_or("actors", cfg.actors)?;
    cfg.learners = a.parse_or("learners", cfg.learners)?;
    cfg.total_env_steps = a.parse_or("steps", cfg.total_env_steps)?;
    apply_service_flags(&mut cfg, a)?;
    cfg.lr = a.parse_or("lr", cfg.lr)?;
    cfg.grad_clip = a.parse_or("grad-clip", cfg.grad_clip)?;
    cfg.aggregation = a.parse_or("aggregation", cfg.aggregation)?;
    cfg.remote_batch = a.parse_or("remote-batch", cfg.remote_batch)?;
    if cfg.remote_batch == 0 {
        bail!("--remote-batch must be >= 1");
    }
    cfg.rpc_timeout_secs = a.seconds_or("rpc-timeout", cfg.rpc_timeout_secs)?.as_secs_f64();
    cfg.reconnect_deadline_secs = a
        .seconds_or("reconnect-deadline", cfg.reconnect_deadline_secs)?
        .as_secs_f64();
    cfg.spill_cap = a.parse_or("spill-cap", cfg.spill_cap)?;
    if cfg.spill_cap == 0 {
        bail!("--spill-cap must be >= 1");
    }
    cfg.mass_ttl_ms = a.parse_or("mass-ttl", cfg.mass_ttl_ms)?;
    if !cfg.mass_ttl_ms.is_finite() || cfg.mass_ttl_ms < 0.0 {
        bail!("--mass-ttl must be a finite number of milliseconds >= 0");
    }
    if let Some(list) = a.get("remote") {
        // One endpoint = one server; several (comma-separated) = a
        // replay mesh. Duplicates are rejected here — a double-dialed
        // server would skew both affinity routing and the
        // mass-proportional draw.
        cfg.remote = parse_endpoint_list(list)?;
        // The tables live in the serving process: local table/buffer/
        // limiter flags do nothing on a remote run, and silently
        // ignoring them would let users believe they applied.
        let server_side: &[&str] = &[
            "tables", "capacity", "shards", "fanout", "alpha", "beta", "warmup",
            "rate-limit", "remove", "buffer", "n-step", "gamma-nstep",
        ];
        let ignored: Vec<&str> = server_side.iter().copied().filter(|f| a.has(f)).collect();
        if !ignored.is_empty() {
            eprintln!(
                "[pal] WARNING: --remote uses the server's table configuration; \
                 ignoring local flags {ignored:?} (set them on `pal serve`)"
            );
        }
    } else {
        for f in ["remote-batch", "rpc-timeout", "reconnect-deadline", "spill-cap", "mass-ttl"] {
            if a.has(f) {
                eprintln!("[pal] WARNING: --{f} only applies to --remote runs; ignored");
            }
        }
    }
    if let Some(dir) = a.get("save-state") {
        cfg.save_state = Some(dir.into());
    }
    if let Some(dir) = a.get("restore-state") {
        cfg.restore_state = Some(dir.into());
    }
    cfg.checkpoint_every_secs = a.parse_or("checkpoint-every", cfg.checkpoint_every_secs)?;
    if cfg.checkpoint_every_secs > 0.0 && cfg.save_state.is_none() {
        bail!("--checkpoint-every requires --save-state DIR");
    }
    cfg.seed = a.parse_or("seed", cfg.seed)?;
    cfg.exploration.eps_decay_steps = a.parse_or("eps-decay", cfg.exploration.eps_decay_steps)?;
    cfg.exploration.action_noise = a.parse_or("action-noise", cfg.exploration.action_noise)?;
    if let Some(r) = a.get("stop-at-reward") {
        cfg.stop_at_reward = Some(r.parse().map_err(|_| anyhow!("bad --stop-at-reward"))?);
    }
    cfg.log_every_secs = a.parse_or("log-every", 5.0)?;
    Ok(cfg)
}

fn cmd_train(a: &Args) -> Result<()> {
    let cfg = train_config_from(a)?;
    eprintln!(
        "[pal] training {} on {} — {} actors, {} learners, buffer={:?}",
        cfg.algo, cfg.env, cfg.actors, cfg.learners, cfg.buffer
    );
    let report = train(&cfg)?;
    println!(
        "done: {} env steps, {} learn steps, {} episodes in {:.1}s \
         ({:.0} env/s, {:.0} learn/s), mean return {:.2}{}",
        report.env_steps,
        report.learn_steps,
        report.episodes,
        report.elapsed_secs,
        report.env_steps_per_sec,
        report.learn_steps_per_sec,
        report.final_mean_return,
        if report.reached_target { " [target reached]" } else { "" },
    );
    for (name, s) in &report.table_stats {
        println!(
            "table {name}: {} inserts, {} batches ({} items), {} priority updates, \
             stalls insert/sample = {}/{}",
            s.inserts,
            s.sample_batches,
            s.sampled_items,
            s.priority_updates,
            s.insert_stalls,
            s.sample_stalls,
        );
    }
    if let Some(path) = a.get("save-checkpoint") {
        pal_rl::params::Checkpoint {
            online: report.final_weights.clone(),
            target: report.final_target_weights.clone(),
            opt_steps: report.opt_steps as u64,
        }
        .save(path)?;
        eprintln!("[pal] checkpoint written to {path}");
    }
    if let Some(path) = a.get("curve-out") {
        let mut csv = String::from("wall_secs,env_steps,learn_steps,episode_return,loss_ema\n");
        for p in &report.curve {
            csv.push_str(&format!(
                "{:.3},{},{},{},{}\n",
                p.wall_secs, p.env_steps, p.learn_steps, p.episode_return, p.loss_ema
            ));
        }
        std::fs::write(path, csv)?;
        eprintln!("[pal] curve written to {path}");
    }
    Ok(())
}

fn cmd_envs() {
    println!("built-in environments:");
    for e in ENV_NAMES {
        let env = pal_rl::env::make_env(e).unwrap();
        let spec = env.spec();
        println!(
            "  {:28} obs_dim={:2} actions={:?} horizon={}",
            spec.name, spec.obs_dim, spec.action_space, spec.max_episode_steps
        );
    }
}

fn cmd_info(a: &Args) -> Result<()> {
    let dir = a.str_or("artifacts", "artifacts");
    let m = Manifest::load(&dir)?;
    println!("manifest at {dir}: {} artifacts", m.artifacts.len());
    for info in m.artifacts.values() {
        println!(
            "  {:32} params={:7} graphs=[{}]",
            info.id,
            info.total_param_size,
            info.graphs.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_buffer_bench(a: &Args) -> Result<()> {
    use pal_rl::replay::*;
    use pal_rl::util::rng::Rng;
    use std::sync::Arc;
    let capacity: usize = a.parse_or("capacity", 100_000)?;
    let fanout: usize = a.parse_or("fanout", 64)?;
    let shards: usize = a.parse_or("shards", 1)?;
    let threads: usize = a.parse_or("threads", 4)?;
    let ops: usize = a.parse_or("ops", 100_000)?;
    let cfg = PrioritizedConfig {
        capacity,
        obs_dim: 8,
        act_dim: 2,
        fanout,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards,
    };
    let buf: Arc<dyn ReplayBuffer> = if shards > 1 {
        Arc::new(ShardedPrioritizedReplay::new(cfg))
    } else {
        Arc::new(PrioritizedReplay::new(cfg))
    };
    let t = Transition {
        obs: vec![0.5; 8],
        action: vec![0.1; 2],
        next_obs: vec![0.6; 8],
        reward: 1.0,
        done: false,
    };
    let prefill = capacity.min(10_000);
    for _ in 0..prefill {
        buf.insert(&t);
    }
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for tid in 0..threads {
            let buf = Arc::clone(&buf);
            let tr = t.clone();
            s.spawn(move || {
                let mut rng = Rng::new(tid as u64);
                let mut out = SampleBatch::default();
                for i in 0..ops / threads {
                    match i % 3 {
                        0 => buf.insert_from(tid, &tr),
                        1 => {
                            buf.sample(32, &mut rng, &mut out);
                        }
                        _ => {
                            // Feed back TDs for the last sampled batch
                            // (keeps updates spread across shards the
                            // way a real learner does).
                            if !out.indices.is_empty() {
                                let idx = out.indices.clone();
                                let tds: Vec<f32> =
                                    idx.iter().map(|_| rng.f32() * 2.0).collect();
                                buf.update_priorities(&idx, &tds);
                            }
                        }
                    }
                }
            });
        }
    });
    let dt = t0.elapsed();
    println!(
        "{} ops across {threads} threads in {:.3}s = {:.0} ops/s \
         (capacity={capacity}, K={fanout}, S={shards}, buffer={})",
        ops,
        dt.as_secs_f64(),
        ops as f64 / dt.as_secs_f64(),
        buf.name(),
    );
    Ok(())
}

const STATE_SMOKE_FLAGS: &[&str] = &["dir", "phase", "items", "capacity", "shards"];
const SMOKE_OBS: usize = 4;
const SMOKE_ACT: usize = 2;

/// The run shape the checkpoint smoke drives: a sharded prioritized
/// learner table under a σ=1 ratio limiter plus a free-running N-step
/// auxiliary table — the config both phases must build identically.
fn smoke_config(a: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::PalKary;
    cfg.buffer_capacity = a.parse_or("capacity", 4_096)?;
    cfg.shards = a.parse_or("shards", 4)?;
    cfg.warmup_steps = 64;
    cfg.rate_limit = RateLimitSpec::SamplesPerInsert(1.0);
    cfg.tables = vec![
        TableSpec {
            name: "replay".into(),
            kind: ItemKind::OneStep,
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
            remove: None,
        },
        TableSpec {
            name: "aux".into(),
            kind: ItemKind::NStep { n: 3, gamma: cfg.gamma_nstep },
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
            remove: None,
        },
    ];
    Ok(cfg)
}

/// Drive `items` synthetic env steps through the service with 2 writer
/// threads + 1 sampler thread (the learner hot loop with the PJRT
/// compute stripped away), exactly like a miniature train run.
fn smoke_traffic(service: &ReplayService, items: usize) {
    use std::sync::atomic::{AtomicBool, Ordering};
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for actor in 0..2usize {
            let mut writer = service.writer(actor);
            handles.push(s.spawn(move || {
                for i in 0..items / 2 {
                    while writer.throttled() {
                        std::thread::yield_now();
                    }
                    writer.append(WriterStep {
                        obs: vec![i as f32; SMOKE_OBS],
                        action: vec![0.1; SMOKE_ACT],
                        next_obs: vec![i as f32 + 1.0; SMOKE_OBS],
                        reward: 1.0,
                        done: i % 32 == 31,
                        truncated: false,
                    });
                }
            }));
        }
        {
            let sampler = service.default_sampler();
            let done = &done;
            s.spawn(move || {
                let mut rng = pal_rl::util::rng::Rng::new(17);
                let mut out = pal_rl::replay::SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    if sampler.try_sample(16, &mut rng, &mut out) == SampleOutcome::Sampled {
                        let idx = out.indices.clone();
                        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
                        sampler.update_priorities(&idx, &tds);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
        for h in handles {
            h.join().expect("writer thread panicked");
        }
        done.store(true, Ordering::Relaxed);
    });
}

/// Checkpoint round-trip smoke (the CI durability gate). `--phase
/// collect` runs synthetic traffic and saves the unified run state;
/// `--phase resume` rebuilds the same service in a NEW process,
/// restores, and asserts element counts, priority mass and limiter
/// counters all equal the snapshotted values, then proves the resumed
/// service still trains (more traffic, ratio bound intact).
fn cmd_state_smoke(a: &Args) -> Result<()> {
    a.check_known(STATE_SMOKE_FLAGS)?;
    let dir: std::path::PathBuf =
        a.get("dir").ok_or_else(|| anyhow!("--dir required"))?.into();
    let items: usize = a.parse_or("items", 2_000)?;
    let cfg = smoke_config(a)?;
    let service = build_service(&cfg, SMOKE_OBS, SMOKE_ACT)?;
    let server = ParameterServer::new(
        vec![0.5; 16],
        AdamConfig::default(),
        TargetSync::None,
        1,
    );
    match a.get("phase") {
        Some("collect") => {
            smoke_traffic(&service, items);
            server.push_gradient(0, 16, &[0.1; 16]);
            save_run_state(&dir, &server, &service)?;
            for t in service.tables() {
                eprintln!("[smoke] saved {}", t.stats_line());
            }
            println!(
                "state-smoke collect OK: {} items saved to {}",
                service.total_len(),
                dir.display()
            );
            Ok(())
        }
        Some("resume") => {
            let state = ServiceState::load(dir.join(STATE_FILE))?;
            restore_run_state(&dir, &server, &service)?;
            for t in service.tables() {
                let ts = state
                    .table(t.name())
                    .ok_or_else(|| anyhow!("table `{}` missing from state", t.name()))?;
                ensure!(
                    t.len() == ts.buffer.len(),
                    "table `{}`: restored {} items, snapshot has {}",
                    t.name(),
                    t.len(),
                    ts.buffer.len()
                );
                ensure!(
                    t.stats_snapshot() == ts.stats,
                    "table `{}`: restored counters {:?} != snapshot {:?}",
                    t.name(),
                    t.stats_snapshot(),
                    ts.stats
                );
            }
            // Priority mass: re-capture the restored service and compare
            // per-table priority sums against the file.
            let recap = ServiceState::capture(&service)?;
            for ts in &state.tables {
                let got = recap.table(&ts.name).unwrap().buffer.total_priority();
                let want = ts.buffer.total_priority();
                ensure!(
                    (got - want).abs() <= want.abs().max(1.0) * 1e-3,
                    "table `{}`: restored priority mass {got} != snapshot {want}",
                    ts.name
                );
            }
            ensure!(server.opt_steps() == 1, "optimizer steps not restored");
            // The resumed service keeps working: more traffic, and the
            // sample-to-insert ratio bound holds over the COMBINED
            // (restored + new) counters.
            let before = service.default_table().stats_snapshot();
            smoke_traffic(&service, 512);
            let after = service.default_table().stats_snapshot();
            ensure!(after.inserts > before.inserts, "resumed run inserted nothing");
            ensure!(
                after.sample_batches as f64 <= after.inserts as f64 + 1e-9,
                "ratio bound violated after resume: {} batches vs {} inserts",
                after.sample_batches,
                after.inserts
            );
            println!(
                "state-smoke resume OK: {} items, priority mass and limiter counters match; \
                 +{} inserts after resume",
                state.total_len(),
                after.inserts - before.inserts
            );
            Ok(())
        }
        other => bail!("--phase must be `collect` or `resume`, got {other:?}"),
    }
}

const SERVE_FLAGS: &[&str] = &[
    "socket", "tcp", "buffer", "capacity", "shards", "fanout", "alpha", "beta",
    "warmup", "update-interval", "n-step", "gamma-nstep", "tables",
    "rate-limit", "remove", "obs-dim", "act-dim", "seed", "restore-state",
    "save-state", "drain-deadline", "writer-budget", "max-writers-per-table",
    "drain-to",
];

/// Set by [`on_stop_signal`] when the serving process receives SIGINT
/// or SIGTERM, polled by the serve watcher thread so Ctrl-C and
/// orchestrator TERMs get the same drain + `--save-state` path a
/// client's Shutdown RPC gets.
static SIGNAL_STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_stop_signal(_sig: i32) {
    // Async-signal-safe: a single atomic store, nothing else.
    SIGNAL_STOP.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGINT (2) and SIGTERM (15) to [`on_stop_signal`]. std has no
/// signal API, so this declares libc's `signal(2)` directly — with a
/// typed handler pointer, not a `usize`, so no function-pointer casts
/// are involved. Installation failure (`SIG_ERR`) is ignored: signals
/// then keep their default disposition and `pal serve` dies the
/// pre-handler way, which is a degraded mode, not an error.
fn install_stop_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_stop_signal);
        signal(SIGTERM, on_stop_signal);
    }
}

/// `pal serve`: build a replay service from the same table/buffer flags
/// `train` uses and expose it on a Unix-domain socket (`--socket`) or a
/// TCP address (`--tcp`), so actors and learners in OTHER processes —
/// or on other hosts (`pal train --remote ENDPOINT[,..]`) — share its
/// tables. Runs until a client sends the Shutdown RPC or the process
/// receives SIGINT/SIGTERM — both take the same drain path, so a clean
/// shutdown (including Ctrl-C) optionally saves the replay state.
fn cmd_serve(a: &Args) -> Result<()> {
    a.check_known(SERVE_FLAGS)?;
    let endpoint = match (a.get("socket"), a.get("tcp")) {
        (Some(path), None) => Endpoint::from(std::path::Path::new(path)),
        (None, Some(addr)) => Endpoint::tcp(addr)?,
        (Some(_), Some(_)) => bail!("--socket and --tcp are mutually exclusive"),
        (None, None) => bail!("--socket PATH or --tcp HOST:PORT required"),
    };
    let mut cfg = TrainConfig::new("serve", "remote");
    apply_service_flags(&mut cfg, a)?;
    let obs_dim: usize = a.parse_or("obs-dim", 4)?;
    let act_dim: usize = a.parse_or("act-dim", 2)?;
    let seed: u64 = a.parse_or("seed", 0)?;
    let drain_deadline = a.seconds_or("drain-deadline", 5.0)?;
    let writer_budget: u64 = a.parse_or("writer-budget", 0)?;
    let max_writers: usize = a.parse_or("max-writers-per-table", 0)?;
    let service = Arc::new(build_service(&cfg, obs_dim, act_dim)?);
    if let Some(dir) = a.get("restore-state") {
        let state = ServiceState::load(std::path::Path::new(dir).join(STATE_FILE))?;
        service.restore(&state)?;
        eprintln!(
            "[pal] replay server restored {} items from {dir}",
            service.total_len()
        );
    }
    let drain_peers = match a.get("drain-to") {
        Some(list) => parse_endpoint_list(list)?,
        None => Vec::new(),
    };
    let server = ReplayServer::bind_endpoint(Arc::clone(&service), &endpoint, seed)?
        .expect_dims(obs_dim, act_dim)
        .with_drain_deadline(drain_deadline)
        .with_quotas(writer_budget, max_writers)
        .with_drain_peers(drain_peers);
    // The RESOLVED endpoint: a `--tcp HOST:0` bind reports the real
    // port here, which is what scripts parse to build client endpoint
    // lists.
    eprintln!(
        "[pal] replay server listening on {} — {}",
        server.endpoint(),
        service.stats_line()
    );
    // SIGINT/SIGTERM flip SIGNAL_STOP; a watcher thread relays that to
    // the server's stop handle so the accept loop drains and returns
    // (signal handlers must not touch the server themselves).
    install_stop_signal_handlers();
    let stop = server.stop_handle();
    let serve_done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let watcher = {
        let stop = Arc::clone(&stop);
        let serve_done = Arc::clone(&serve_done);
        std::thread::spawn(move || {
            use std::sync::atomic::Ordering;
            while !serve_done.load(Ordering::Relaxed) {
                if SIGNAL_STOP.load(Ordering::SeqCst) {
                    eprintln!("[pal] stop signal received — draining replay server");
                    stop.store(true, Ordering::SeqCst);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
        })
    };
    let served = server.serve();
    serve_done.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = watcher.join();
    served?;
    if let Some(dir) = a.get("save-state") {
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        ServiceState::capture(&service)?.save(dir.join(STATE_FILE))?;
        eprintln!(
            "[pal] replay state saved to {} ({} items)",
            dir.display(),
            service.total_len()
        );
    }
    eprintln!("[pal] replay server stopped — {}", service.stats_line());
    Ok(())
}

const DRAIN_FLAGS: &[&str] = &["endpoint", "to", "chunk"];

/// `pal drain`: tell a running `pal serve` to leave the mesh. The
/// server stops admitting appends and new sessions, hands its tables
/// to the first reachable peer over the chunked transfer stream —
/// `--to` names the candidates, falling back to the server's own
/// `--drain-to` list — and shuts down once the handoff lands. A failed
/// handoff (no peers, all unreachable) leaves the server serving.
fn cmd_drain(a: &Args) -> Result<()> {
    a.check_known(DRAIN_FLAGS)?;
    let ep =
        Endpoint::parse(a.get("endpoint").ok_or_else(|| anyhow!("--endpoint EP required"))?)?;
    // Parsed locally too, so a typo is an immediate CLI error instead
    // of a refused drain reported by the server.
    let peers: Vec<String> = match a.get("to") {
        Some(list) => parse_endpoint_list(list)?.iter().map(|p| p.to_string()).collect(),
        None => Vec::new(),
    };
    let chunk: u32 = a.parse_or("chunk", 0)?;
    let mut client = RemoteClient::connect_endpoint(&ep)?;
    let held: u64 = client.stats()?.iter().map(|t| t.len).sum();
    client.drain(&peers, chunk)?;
    println!("drain OK: {ep} handed its {held} items to a peer and is shutting down");
    Ok(())
}

const REMOTE_SMOKE_FLAGS: &[&str] = &["socket", "items", "capacity", "shards"];

/// Seed of the deterministic phase's sampling RNG — the remote
/// connection's server-side RNG (via Hello) and the in-process twin's
/// local RNG, so the two runs draw identical index sequences.
const REMOTE_SMOKE_SEED: u64 = 0x5EED_50CC;

/// One synthetic env step of the remote smoke's traffic.
fn smoke_step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32; SMOKE_OBS],
        action: vec![0.1; SMOKE_ACT],
        next_obs: vec![i as f32 + 1.0; SMOKE_OBS],
        reward: 1.0,
        done: i % 32 == 31,
        truncated: false,
    }
}

/// Client-side append batch of the smoke's remote writer, and the
/// group size of [`deterministic_drive`] — the two must agree so the
/// batched remote appends land on the server exactly where the
/// in-process twin's writer has inserted them.
const REMOTE_SMOKE_BATCH: usize = 16;

/// Deterministic collect/sample loop over trait-level handles, so the
/// EXACT same call sequence can run against a remote server and an
/// in-process service. Steps go in `chunk`-aligned groups (the remote
/// writer's `--remote-batch`), each group followed by one
/// sample+priority-update round per step past `warmup`, which with the
/// smoke's σ=1 ratio limiter keeps the drift window open — the loop
/// never stalls, so even the stall counters of the two runs stay
/// equal. Returns the number of granted batches.
fn deterministic_drive(
    w: &mut dyn ExperienceWriter,
    s: &mut dyn ExperienceSampler,
    rng: &mut Rng,
    warmup: usize,
    items: usize,
    chunk: usize,
) -> Result<u64> {
    let mut out = SampleBatch::default();
    let mut batches = 0u64;
    let mut start = 0usize;
    while start < items {
        let group = chunk.min(items - start);
        for i in start..start + group {
            ensure!(
                !w.throttled()?,
                "deterministic phase writer unexpectedly throttled at item {i}"
            );
            w.append(smoke_step(i))?;
        }
        // A partial tail group (items not a chunk multiple) still has
        // to land before its samples; a full group already shipped at
        // the batching threshold.
        ensure!(
            w.flush()? == 0,
            "deterministic phase writer stalled flushing at item {start}"
        );
        for i in start..start + group {
            if i < warmup {
                continue;
            }
            match s.try_sample(16, rng, &mut out)? {
                SampleOutcome::Sampled => {
                    batches += 1;
                    let idx = out.indices.clone();
                    // Priorities are a pure function of (round, slot) so
                    // both runs feed identical values.
                    let tds: Vec<f32> = (0..idx.len())
                        .map(|j| ((batches * 31 + j as u64) % 97) as f32 * 0.1 + 0.05)
                        .collect();
                    s.update_priorities(&idx, &tds)?;
                }
                other => bail!("deterministic phase stalled sampling at item {i}: {other:?}"),
            }
        }
        start += group;
    }
    Ok(batches)
}

/// Deterministic pipelined-sampling phase: `rounds` lockstep
/// sample+update rounds with prefetch enabled remotely and a plain
/// in-process sampler locally. With no appends interleaved, the
/// prefetch (drawn right after each update, before the next
/// `try_sample`) sees exactly the state the local sampler sees, so the
/// two stay bit-identical. The trailing in-flight prefetch is drained
/// and mirrored with one extra local draw, keeping the counters — and
/// the checkpoints — equal. Returns `(granted, updated)` batch counts
/// (the drained prefetch is granted but never priority-updated).
fn prefetch_lockstep_drive(
    remote: &mut RemoteSampler,
    local: &pal_rl::service::SamplerHandle,
    local_rng: &mut Rng,
    rounds: usize,
) -> Result<(u64, u64)> {
    let mut unused = Rng::new(7); // remote sampling uses the server-side RNG
    let mut remote_out = SampleBatch::default();
    let mut local_out = SampleBatch::default();
    let mut batches = 0u64;
    for round in 0..rounds {
        let r = remote.try_sample(16, &mut unused, &mut remote_out)?;
        let l = local.try_sample(16, local_rng, &mut local_out);
        ensure!(r == l, "prefetch round {round}: outcomes diverged ({r:?} vs {l:?})");
        ensure!(r == SampleOutcome::Sampled, "prefetch round {round} stalled: {r:?}");
        ensure!(
            remote_out.indices == local_out.indices,
            "prefetch round {round}: sampled indices diverged"
        );
        batches += 1;
        let tds: Vec<f32> = (0..remote_out.indices.len())
            .map(|j| ((round * 17 + j) % 89) as f32 * 0.1 + 0.05)
            .collect();
        remote.update_priorities(&remote_out.indices, &tds)?;
        local.update_priorities(&local_out.indices, &tds);
    }
    let updates = batches;
    // The pipeline's trailing prefetch is a batch the server already
    // granted and counted; mirror it locally so both sides' counters
    // (and therefore their checkpoints) stay identical.
    if let Some(outcome) = remote.drain()? {
        let l = local.try_sample(16, local_rng, &mut local_out);
        ensure!(
            outcome == l,
            "drained prefetch outcome {outcome:?} diverged from local {l:?}"
        );
        if outcome == SampleOutcome::Sampled {
            batches += 1;
        }
    }
    Ok((batches, updates))
}

/// Remote round-trip smoke (the CI gate for the socket front-end), run
/// against a FRESHLY started `pal serve` on the same table layout as
/// `state-smoke` (tools/remote_smoke.sh starts it with matching flags):
///
/// 1. deterministic phase — one BATCHED writer (`--remote-batch`-style
///    chunks) + one seeded sampler drive the server through
///    `RemoteWriter`/`RemoteSampler`, the identical loop drives an
///    in-process twin service;
/// 2. deterministic prefetch phase — a pipelined sampler (one batch in
///    flight behind every priority update) runs lockstep against the
///    twin; after both phases the two checkpoints must be
///    BYTE-identical (items, priorities, stats, limiter counters);
/// 3. concurrent soak — two batched writer clients + one pipelined
///    sampler client hammer the server; every sampled batch must be
///    zero-priority-free and the final Stats must account for every
///    client-side operation exactly (inserts, batches, items,
///    priority updates);
/// 4. Shutdown RPC — the serving process exits cleanly (and writes its
///    `--save-state`, which the script asserts).
fn cmd_remote_smoke(a: &Args) -> Result<()> {
    a.check_known(REMOTE_SMOKE_FLAGS)?;
    let socket = a
        .get("socket")
        .ok_or_else(|| anyhow!("--socket PATH required"))?
        .to_string();
    let items: usize = a.parse_or("items", 2_000)?;
    let cfg = smoke_config(a)?;
    ensure!(
        items >= cfg.warmup_steps * 4,
        "--items {items} too small for warmup {}",
        cfg.warmup_steps
    );

    // The server must be fresh: the deterministic comparison assumes
    // both sides start from empty tables.
    let before = RemoteClient::connect(&socket)?.stats()?;
    ensure!(
        before.iter().all(|t| t.len == 0 && t.stats.inserts == 0),
        "remote-smoke needs a freshly started server (tables already hold data)"
    );
    ensure!(!before.is_empty(), "server reports no tables");

    // Phase 1a: deterministic drive over the wire, appends batched.
    let mut remote_writer = RemoteWriter::connect(&socket, 0)?.with_batch(REMOTE_SMOKE_BATCH);
    let mut remote_sampler = RemoteSampler::connect_default(&socket, REMOTE_SMOKE_SEED)?;
    let mut unused_rng = Rng::new(1); // remote sampling uses the server-side RNG
    let remote_batches = deterministic_drive(
        &mut remote_writer,
        &mut remote_sampler,
        &mut unused_rng,
        cfg.warmup_steps,
        items,
        REMOTE_SMOKE_BATCH,
    )?;

    // Phase 1b: the identical drive against an in-process twin.
    let local = build_service(&cfg, SMOKE_OBS, SMOKE_ACT)?;
    let mut local_writer = local.writer(0);
    let mut local_sampler = local.default_sampler();
    let mut local_rng = Rng::new(REMOTE_SMOKE_SEED);
    let local_batches = deterministic_drive(
        &mut local_writer,
        &mut local_sampler,
        &mut local_rng,
        cfg.warmup_steps,
        items,
        REMOTE_SMOKE_BATCH,
    )?;
    ensure!(
        remote_batches == local_batches,
        "granted batches diverged: remote {remote_batches} vs local {local_batches}"
    );

    // Phase 2: pipelined sampling in lockstep with the twin. A fresh
    // seeded connection on each side; prefetched batches must track
    // the in-process draws exactly.
    let prefetch_seed = REMOTE_SMOKE_SEED ^ 0xA5A5;
    let mut prefetch_sampler =
        RemoteSampler::connect_default(&socket, prefetch_seed)?.with_prefetch(true);
    let mut prefetch_rng = Rng::new(prefetch_seed);
    let (prefetch_batches, prefetch_updates) = prefetch_lockstep_drive(
        &mut prefetch_sampler,
        &local.default_sampler(),
        &mut prefetch_rng,
        32,
    )?;

    // The wire must not change the state: byte-identical checkpoints
    // after batched appends AND pipelined sampling.
    let remote_bytes = RemoteClient::connect(&socket)?.checkpoint_bytes()?;
    let local_bytes = ServiceState::capture(&local)?.encode();
    if remote_bytes != local_bytes {
        let first_diff = remote_bytes
            .iter()
            .zip(&local_bytes)
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| remote_bytes.len().min(local_bytes.len()));
        bail!(
            "remote checkpoint differs from the in-process twin: {} vs {} bytes, \
             first difference at offset {first_diff}",
            remote_bytes.len(),
            local_bytes.len()
        );
    }
    eprintln!(
        "[smoke] deterministic phase OK: {} items (batch {REMOTE_SMOKE_BATCH}), \
         {remote_batches}+{prefetch_batches} batches (plain+prefetch), \
         checkpoints byte-identical ({} bytes)",
        items,
        remote_bytes.len()
    );
    // Quiesce deterministic connections so the final Shutdown drains fast.
    drop(remote_writer);
    drop(remote_sampler);
    drop(prefetch_sampler);

    // Phase 3: concurrent soak through separate client connections —
    // batched writers, pipelined sampler.
    let soak_each = (items / 4).max(64);
    let done = std::sync::atomic::AtomicBool::new(false);
    let soak_batches = std::sync::atomic::AtomicUsize::new(0);
    let soak_updates = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| -> Result<()> {
        let mut writers = Vec::new();
        for actor in 1..3usize {
            let socket = socket.clone();
            writers.push(s.spawn(move || -> Result<()> {
                let mut w =
                    RemoteWriter::connect(&socket, actor as u64)?.with_batch(REMOTE_SMOKE_BATCH);
                // Bounded waits so a dead sampler fails the smoke
                // instead of hanging CI.
                let wait_admitted = |w: &mut RemoteWriter| -> Result<()> {
                    let mut spins = 0u32;
                    while w.throttled()? {
                        spins += 1;
                        ensure!(spins < 60_000, "soak writer stalled >60s (sampler dead?)");
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Ok(())
                };
                for i in 0..soak_each {
                    wait_admitted(&mut w)?;
                    w.append(smoke_step(actor * 1_000_000 + i))?;
                }
                // Drain: the sub-batch tail AND any steps the limiter
                // stalled must still land before the tally.
                let mut spins = 0u32;
                while w.flush()? > 0 {
                    spins += 1;
                    ensure!(spins < 60_000, "soak writer could not drain (sampler dead?)");
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Ok(())
            }));
        }
        let sampler_handle = {
            let socket = socket.clone();
            let done = &done;
            let soak_batches = &soak_batches;
            let soak_updates = &soak_updates;
            s.spawn(move || -> Result<()> {
                let mut sampler = RemoteSampler::connect_default(&socket, 99)?.with_prefetch(true);
                let mut rng = Rng::new(99);
                let mut out = SampleBatch::default();
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    match sampler.try_sample(16, &mut rng, &mut out)? {
                        SampleOutcome::Sampled => {
                            ensure!(
                                out.priorities.iter().all(|&p| p > 0.0),
                                "sampled a zero-priority item over the wire"
                            );
                            soak_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0 + 0.01).collect();
                            sampler.update_priorities(&idx, &tds)?;
                            soak_updates.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        _ => std::thread::yield_now(),
                    }
                }
                // The pipeline's trailing prefetch is a granted batch
                // the server counted; tally it so the Stats accounting
                // below stays exact.
                if sampler.drain()? == Some(SampleOutcome::Sampled) {
                    soak_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Ok(())
            })
        };
        // Collect every outcome BEFORE propagating any error: an early
        // return would leave `done` unset and the scope joining a
        // sampler that never exits.
        let writer_results: Vec<_> = writers.into_iter().map(|h| h.join()).collect();
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        let sampler_result = sampler_handle.join();
        for r in writer_results {
            r.map_err(|_| anyhow!("soak writer panicked"))??;
        }
        sampler_result.map_err(|_| anyhow!("soak sampler panicked"))??;
        Ok(())
    })?;
    let soak_batches = soak_batches.load(std::sync::atomic::Ordering::Relaxed) as u64;
    let soak_updates = soak_updates.load(std::sync::atomic::Ordering::Relaxed) as u64;

    // Exact accounting across the wire, against the final Stats.
    let stats = RemoteClient::connect(&socket)?.stats()?;
    ensure!(!stats.is_empty(), "server reports no tables after the soak");
    let total_inserts = items + 2 * soak_each;
    let total_batches = remote_batches + prefetch_batches + soak_batches;
    // Drained trailing prefetches are granted batches that never got a
    // priority update, so updates are tracked separately.
    let total_updates = remote_batches + prefetch_updates + soak_updates;
    for t in &stats {
        ensure!(t.len > 0, "table `{}` is empty after the smoke", t.name);
        ensure!(
            t.len <= t.capacity,
            "table `{}` overflows its capacity",
            t.name
        );
        // The 1-step learner table gets exactly one item per appended
        // step. N-step tables legitimately emit up to n−1 fewer items
        // per writer whose final episode never terminated (the partial
        // window tail is only flushed at a boundary).
        let slack = if t.name == stats[0].name { 0 } else { 3 * 3 };
        ensure!(
            t.stats.inserts <= total_inserts && t.stats.inserts + slack >= total_inserts,
            "table `{}`: {} inserts recorded, clients performed {total_inserts}",
            t.name,
            t.stats.inserts
        );
    }
    let replay = &stats[0];
    ensure!(
        replay.stats.sample_batches as u64 == total_batches,
        "table `{}`: {} batches recorded, clients drew {total_batches}",
        replay.name,
        replay.stats.sample_batches
    );
    ensure!(
        replay.stats.sampled_items as u64 == 16 * total_batches,
        "sampled-items accounting off: {} != 16·{total_batches}",
        replay.stats.sampled_items
    );
    ensure!(
        replay.stats.priority_updates as u64 == 16 * total_updates,
        "priority-update accounting off: {} != 16·{total_updates}",
        replay.stats.priority_updates
    );
    // The σ=1 ratio bound holds over the combined phases.
    ensure!(
        replay.stats.sample_batches <= replay.stats.inserts,
        "ratio bound violated: {} batches vs {} inserts",
        replay.stats.sample_batches,
        replay.stats.inserts
    );
    eprintln!(
        "[smoke] soak OK: +{} inserts, {soak_batches} batches, stalls i/s = {}/{}",
        2 * soak_each,
        replay.stats.insert_stalls,
        replay.stats.sample_stalls
    );

    RemoteClient::connect(&socket)?.shutdown()?;
    println!(
        "remote-smoke OK: {total_inserts} inserts, {total_batches} batches, \
         byte-identical checkpoint, exact accounting over the wire"
    );
    Ok(())
}

const TENANT_SMOKE_FLAGS: &[&str] = &["socket"];

/// Transition dims of the tenant smoke's tables — deliberately NOT the
/// other smokes' 4/2, so a script wiring the wrong server into this
/// gate fails fast on the dim handshake instead of deep in accounting.
const TENANT_OBS: usize = 2;
const TENANT_ACT: usize = 1;

/// One synthetic env step of the tenant smoke's traffic.
fn tenant_step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32; TENANT_OBS],
        action: vec![0.5; TENANT_ACT],
        next_obs: vec![i as f32 + 1.0; TENANT_OBS],
        reward: 1.0,
        done: false,
        truncated: false,
    }
}

fn tenant_table<'a>(stats: &'a [TableInfo], name: &str) -> Result<&'a TableInfo> {
    stats
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow!("table `{name}` missing from Stats"))
}

/// Multi-tenant serving smoke (the CI gate for writer budgets, table
/// ACLs and pluggable eviction over the wire), run by
/// tools/remote_smoke.sh against a `pal serve` started with:
///
/// ```text
/// --tables "hot=1step@16,remove=lifo,cold=1step@16"
/// --obs-dim 2 --act-dim 1 --warmup 1 --rate-limit unlimited
/// --writer-budget 48 --max-writers-per-table 1
/// --restore-state DIR   # a committed legacy PALSTAT1 checkpoint:
///                       # hot = 5 rows, cold = 3 rows
/// ```
///
/// and asserts, in order: the legacy checkpoint restored (5 + 3 rows
/// visible over Stats — v1 files must keep reading under PALSTAT2
/// code); an unknown table in a hello ACL is rejected at the
/// handshake; tenant A (ACL `hot`) gets exactly its 48-step budget —
/// a 60-step append partially consumes 48, the retry consumes 0 —
/// with the 37 overflow evictions charged to LIFO; A touching `cold`
/// is an ACL error that does NOT kill the connection; tenant B (ACL
/// `cold`) appends 20 (7 FIFO evictions) and samples freely; tenant C
/// cannot write `hot` while A holds its writer slot (cap 1); and the
/// final Stats show exact per-tenant insert, eviction and
/// sample-count accounting.
fn cmd_tenant_smoke(a: &Args) -> Result<()> {
    a.check_known(TENANT_SMOKE_FLAGS)?;
    let socket = a
        .get("socket")
        .ok_or_else(|| anyhow!("--socket PATH required"))?
        .to_string();

    // Gate 1: the legacy (PALSTAT1) checkpoint restored. A miss here
    // means forward-compat broke: v1 files must restore under v2 code
    // with FIFO state and zeroed sample counts defaulted in.
    let mut monitor = RemoteClient::connect(&socket)?;
    let before = monitor.stats()?;
    let hot0 = tenant_table(&before, "hot")?.clone();
    let cold0 = tenant_table(&before, "cold")?.clone();
    ensure!(
        hot0.len == 5 && hot0.capacity == 16 && hot0.stats.inserts == 5,
        "hot table did not restore from the legacy checkpoint: {hot0:?}"
    );
    ensure!(
        cold0.len == 3 && cold0.capacity == 16 && cold0.stats.inserts == 3,
        "cold table did not restore from the legacy checkpoint: {cold0:?}"
    );
    ensure!(
        hot0.stats.max_times_sampled == 0 && cold0.stats.max_times_sampled == 0,
        "legacy restore must default sample counts to zero"
    );
    eprintln!("[tenant] legacy PALSTAT1 checkpoint restored: hot=5 cold=3 rows");

    // Gate 2: a hello ACL naming an unknown table is a handshake
    // error, not a silent no-op.
    let mut bad = RemoteClient::connect(&socket)?;
    bad.set_acl(vec!["nope".into()]);
    let err = match bad.hello(7) {
        Err(e) => format!("{e:#}"),
        Ok(t) => bail!("hello with a bogus ACL succeeded (default table `{t}`)"),
    };
    ensure!(
        err.contains("unknown table"),
        "bogus-ACL hello failed with the wrong error: {err}"
    );
    drop(bad);

    // Tenant A: ACL {hot}, budget 48. A 60-step append must partially
    // consume exactly the budget; the overflow past hot's 11 free
    // slots (16 − 5 restored) evicts 37 items by the table's LIFO
    // policy.
    let mut a_cli = RemoteClient::connect(&socket)?;
    a_cli.set_acl(vec!["hot".into()]);
    a_cli.hello(11)?;
    let steps_a: Vec<WriterStep> = (0..60usize).map(tenant_step).collect();
    let (consumed, emitted) = a_cli.append(1, &steps_a)?;
    ensure!(
        (consumed, emitted) == (48, 48),
        "tenant A: expected the 60-step append to consume its 48-step \
         budget exactly, got consumed {consumed} emitted {emitted}"
    );
    let (consumed, _) = a_cli.append(1, &steps_a[..1])?;
    ensure!(
        consumed == 0,
        "tenant A: append past an exhausted budget consumed {consumed} steps"
    );
    // An ACL violation is an application error on a healthy
    // connection: the Error frame comes back, the session lives on.
    let err = match a_cli.update_priorities("cold", &[0], &[1.0]) {
        Err(e) => format!("{e:#}"),
        Ok(()) => bail!("tenant A updated priorities on a table outside its ACL"),
    };
    ensure!(err.contains("ACL"), "ACL violation surfaced the wrong error: {err}");
    a_cli
        .stats()
        .map_err(|e| anyhow!("tenant A's connection died after an ACL error: {e:#}"))?;

    // Tenant B: ACL {cold}. 20 appends overflow cold's 13 free slots
    // by 7 — evicted FIFO (the run default) — then sampling is free
    // (warmup 1, unlimited limiter) and drives the per-item sample
    // counts the Stats must report.
    let mut b_cli = RemoteClient::connect(&socket)?;
    b_cli.set_acl(vec!["cold".into()]);
    b_cli.hello(22)?;
    let steps_b: Vec<WriterStep> = (100..120usize).map(tenant_step).collect();
    let (consumed, emitted) = b_cli.append(2, &steps_b)?;
    ensure!(
        (consumed, emitted) == (20, 20),
        "tenant B: expected all 20 steps consumed, got {consumed}/{emitted}"
    );
    let mut out = SampleBatch::default();
    for round in 0..3 {
        let outcome = b_cli.sample("cold", 8, &mut out)?;
        ensure!(
            outcome == SampleOutcome::Sampled,
            "tenant B: sample round {round} stalled: {outcome:?}"
        );
    }

    // Tenant C: ACL {hot}, but --max-writers-per-table 1 and tenant A
    // still holds hot's writer slot — the claim must fail as a
    // RETRIABLE would-stall (consumed 0), not a connection error.
    let mut c_cli = RemoteClient::connect(&socket)?;
    c_cli.set_acl(vec!["hot".into()]);
    c_cli.hello(33)?;
    let (consumed, _) = c_cli.append(3, &steps_a[..1])?;
    ensure!(
        consumed == 0,
        "tenant C: wrote {consumed} steps to `hot` past the writers-per-table cap"
    );

    // Exact per-tenant accounting over the final Stats.
    let after = monitor.stats()?;
    let hot = tenant_table(&after, "hot")?.clone();
    let cold = tenant_table(&after, "cold")?.clone();
    ensure!(
        hot.stats.inserts == hot0.stats.inserts + 48,
        "hot inserts: {} recorded, tenant A consumed 48 over {}",
        hot.stats.inserts,
        hot0.stats.inserts
    );
    ensure!(hot.len == 16, "hot should sit at capacity, len {}", hot.len);
    ensure!(
        hot.stats.evict_lifo == 37 && hot.stats.evict_fifo == 0,
        "hot evictions must all be LIFO: lifo {} fifo {}",
        hot.stats.evict_lifo,
        hot.stats.evict_fifo
    );
    ensure!(
        hot.stats.sample_batches == hot0.stats.sample_batches
            && hot.stats.max_times_sampled == 0,
        "nobody sampled hot: batches {} (was {}), max_times_sampled {}",
        hot.stats.sample_batches,
        hot0.stats.sample_batches,
        hot.stats.max_times_sampled
    );
    ensure!(
        cold.stats.inserts == cold0.stats.inserts + 20,
        "cold inserts: {} recorded, tenant B consumed 20 over {}",
        cold.stats.inserts,
        cold0.stats.inserts
    );
    ensure!(cold.len == 16, "cold should sit at capacity, len {}", cold.len);
    ensure!(
        cold.stats.evict_fifo == 7 && cold.stats.evict_lifo == 0,
        "cold evictions must all be FIFO: fifo {} lifo {}",
        cold.stats.evict_fifo,
        cold.stats.evict_lifo
    );
    ensure!(
        cold.stats.sample_batches == cold0.stats.sample_batches + 3
            && cold.stats.sampled_items == cold0.stats.sampled_items + 24,
        "cold sampling accounting off: batches {} items {}",
        cold.stats.sample_batches,
        cold.stats.sampled_items
    );
    // 24 draws over at most 16 occupied slots: some slot was sampled
    // at least twice (pigeonhole), and the count must survive into the
    // snapshot the Stats RPC reports.
    ensure!(
        cold.stats.max_times_sampled >= 2,
        "cold max_times_sampled {} after 24 draws over 16 slots",
        cold.stats.max_times_sampled
    );

    drop(a_cli);
    drop(b_cli);
    drop(c_cli);
    monitor.shutdown()?;
    println!(
        "tenant-smoke OK: legacy checkpoint restored, budgets and ACLs enforced, \
         hot +48 inserts (37 LIFO evictions), cold +20 inserts (7 FIFO evictions, \
         max sample count {})",
        cold.stats.max_times_sampled
    );
    Ok(())
}

const MESH_SMOKE_FLAGS: &[&str] = &["endpoints", "items", "capacity", "shards"];

/// Seed of the mesh smoke: the client-side level-1 (server pick) RNG,
/// and — via [`pal_rl::remote::mesh::server_seed`] — every server's
/// session sampling RNG, so the in-process twins can replay the whole
/// two-level draw.
const MESH_SMOKE_SEED: u64 = 0x5EED_3E54;

/// Chunk size the mesh smoke forces on its state transfers: small
/// enough that every checkpoint/restore crosses the wire as MANY
/// bounded frames (the contract the chunked stream exists for), not
/// one frame that happens to fit.
const MESH_SMOKE_CHUNK: usize = 4_096;

/// Twin image of the mesh sampler's level-1 server pick: an f64 prefix
/// scan over the advertised masses that skips zero-mass servers while
/// tracking the last positive one. Must match `MeshSampler` exactly —
/// the smoke replays its draw against in-process twins.
fn twin_pick(masses: &[(u64, f32)], x: f64) -> Option<usize> {
    let mut sel = None;
    let mut acc = 0.0f64;
    for (k, &(_, m)) in masses.iter().enumerate() {
        let m = f64::from(m);
        if m > 0.0 {
            sel = Some(k);
            if acc + m >= x {
                break;
            }
        }
        acc += m;
    }
    sel
}

/// Cross-host replay mesh smoke (the CI gate for `--remote EP1,EP2`),
/// run against N freshly started `pal serve` processes on the same
/// table layout as `remote-smoke` but with an unlimited rate limiter
/// (the mesh's mass-proportional server pick is random, so a σ-ratio
/// limiter on a briefly under-picked server would stall the
/// deterministic drive):
///
/// 1. affinity appends — one batched [`MeshWriter`] per server (actor
///    `a` → server `a % N`), mirrored into N in-process twin services;
/// 2. two-level sampling — a seeded [`MeshSampler`] draws
///    sample+priority-update rounds while the smoke replays the whole
///    draw (mass probe, server pick, within-server indices) against
///    the twins; every batch must match index-for-index;
/// 3. per-server checkpoints — downloaded over the chunked transfer
///    stream in deliberately tiny frames, each byte-identical to its
///    twin's state; then a full mesh checkpoint/restore round-trip
///    (including a tiny-chunk upload) must leave every server
///    byte-identical again;
/// 4. exact accounting — each server's Stats must equal the
///    client-side per-server tallies (inserts, batches, sampled items,
///    priority updates); then every server is shut down via RPC.
fn cmd_mesh_smoke(a: &Args) -> Result<()> {
    a.check_known(MESH_SMOKE_FLAGS)?;
    let list = a
        .get("endpoints")
        .ok_or_else(|| anyhow!("--endpoints EP1,EP2[,..] required"))?;
    let endpoints = parse_endpoint_list(list)?;
    let n = endpoints.len();
    ensure!(n >= 2, "mesh-smoke needs at least 2 endpoints, got {n}");
    let items: usize = a.parse_or("items", 2_000)?;
    let per_server = items / n;
    let mut cfg = smoke_config(a)?;
    cfg.rate_limit = RateLimitSpec::Unlimited;
    ensure!(
        per_server >= cfg.warmup_steps * 2,
        "--items {items} too small for warmup {} across {n} servers",
        cfg.warmup_steps
    );
    let policy = ConnectionPolicy::default();

    // The servers must be fresh: the lockstep comparison assumes every
    // table starts empty.
    for (s, ep) in endpoints.iter().enumerate() {
        let stats = RemoteClient::connect_endpoint(ep)?.stats()?;
        ensure!(!stats.is_empty(), "mesh server {s} ({ep}) reports no tables");
        ensure!(
            stats.iter().all(|t| t.len == 0 && t.stats.inserts == 0),
            "mesh-smoke needs freshly started servers (server {s} ({ep}) already holds data)"
        );
    }
    let twins: Vec<ReplayService> = (0..n)
        .map(|_| build_service(&cfg, SMOKE_OBS, SMOKE_ACT))
        .collect::<Result<_>>()?;

    // Phase 1: affinity appends — mesh writer per actor, twin writer on
    // the service that actor's id routes to. Same ids, same steps, so
    // server-side shard placement (actor_id % shards) mirrors too.
    for actor in 0..n {
        let mut w = MeshWriter::connect(&endpoints, actor as u64, policy.clone())?
            .with_batch(REMOTE_SMOKE_BATCH);
        ensure!(
            w.server() == actor % n,
            "actor {actor} routed to server {} (expected {})",
            w.server(),
            actor % n
        );
        let mut tw = twins[actor % n].writer(actor);
        for i in 0..per_server {
            let step = smoke_step(actor * 1_000_000 + i);
            ensure!(!w.throttled()?, "mesh writer {actor} throttled under an unlimited limiter");
            w.append(step.clone())?;
            tw.append(step);
        }
        ensure!(w.flush()? == 0, "mesh writer {actor} could not drain its batch tail");
    }

    // Phase 2: two-level sampling, replaying the mesh draw on the twins.
    let mut sampler = MeshSampler::connect_default(&endpoints, MESH_SMOKE_SEED, policy.clone())?;
    ensure!(sampler.table() == "replay", "unexpected default table `{}`", sampler.table());
    ensure!(sampler.server_count() == n, "sampler sees {} servers", sampler.server_count());
    let stride = sampler.stride();
    ensure!(
        stride == cfg.buffer_capacity,
        "mesh stride {stride} != per-server capacity {}",
        cfg.buffer_capacity
    );
    let mut mesh_rng = Rng::new(MESH_SMOKE_SEED); // twin of the level-1 pick RNG
    let mut twin_rngs: Vec<Rng> = (0..n)
        .map(|s| Rng::new(pal_rl::remote::mesh::server_seed(MESH_SMOKE_SEED, s)))
        .collect();
    let twin_samplers: Vec<_> = twins.iter().map(|t| t.default_sampler()).collect();
    let mut dummy_rng = Rng::new(1); // mesh sampling draws server-side
    let mut out = SampleBatch::default();
    let mut twin_out = SampleBatch::default();
    let rounds = per_server / 2;
    let mut batches = vec![0usize; n];
    for round in 0..rounds {
        let outcome = sampler.try_sample(16, &mut dummy_rng, &mut out)?;
        ensure!(outcome == SampleOutcome::Sampled, "mesh round {round} stalled: {outcome:?}");
        // Twin level-1: same masses (bit-equal trees), same draw.
        let masses: Vec<(u64, f32)> = twins
            .iter()
            .map(|t| {
                let tab = t.default_table();
                (tab.len() as u64, tab.total_priority())
            })
            .collect();
        let total_mass: f64 = masses.iter().map(|&(_, m)| f64::from(m)).sum();
        let x = mesh_rng.f64() * total_mass;
        let sel = twin_pick(&masses, x)
            .ok_or_else(|| anyhow!("twin pick found no positive-mass server at round {round}"))?;
        let t_outcome = twin_samplers[sel].try_sample(16, &mut twin_rngs[sel], &mut twin_out);
        ensure!(
            t_outcome == SampleOutcome::Sampled,
            "twin of server {sel} stalled at round {round}: {t_outcome:?}"
        );
        let global: Vec<usize> = twin_out.indices.iter().map(|&i| i + sel * stride).collect();
        ensure!(
            out.indices == global,
            "round {round}: mesh indices diverged from twin of server {sel}"
        );
        ensure!(
            out.priorities == twin_out.priorities,
            "round {round}: sampled priorities diverged from twin of server {sel}"
        );
        batches[sel] += 1;
        // Priorities are a pure function of (round, slot) so both sides
        // feed identical values.
        let tds: Vec<f32> = (0..out.indices.len())
            .map(|j| ((round * 13 + j) % 91) as f32 * 0.1 + 0.05)
            .collect();
        sampler.update_priorities(&out.indices, &tds)?;
        twin_samplers[sel].update_priorities(&twin_out.indices, &tds);
    }
    ensure!(
        batches.iter().all(|&b| b > 0),
        "mass-proportional pick never chose some server (batches {batches:?})"
    );

    // Phase 3a: per-server checkpoints over the chunked stream, in
    // deliberately tiny frames, byte-identical to the twins.
    let mut state_bytes = 0usize;
    for (s, ep) in endpoints.iter().enumerate() {
        let remote_bytes =
            RemoteClient::connect_endpoint(ep)?.checkpoint_bytes_chunked(MESH_SMOKE_CHUNK)?;
        let twin_bytes = ServiceState::capture(&twins[s])?.encode();
        if remote_bytes != twin_bytes {
            let first_diff = remote_bytes
                .iter()
                .zip(&twin_bytes)
                .position(|(a, b)| a != b)
                .unwrap_or_else(|| remote_bytes.len().min(twin_bytes.len()));
            bail!(
                "server {s} ({ep}) checkpoint differs from its twin: {} vs {} bytes, \
                 first difference at offset {first_diff}",
                remote_bytes.len(),
                twin_bytes.len()
            );
        }
        ensure!(
            remote_bytes.len() > MESH_SMOKE_CHUNK,
            "server {s} state ({} bytes) fits one {MESH_SMOKE_CHUNK}-byte chunk — the smoke \
             must exercise a multi-frame stream",
            remote_bytes.len()
        );
        state_bytes += remote_bytes.len();
    }

    // Phase 3b: mesh-wide checkpoint/restore round-trip — including a
    // tiny-chunk upload — must leave every server byte-identical.
    let states = sampler.checkpoint_states()?;
    sampler.restore_states(&states)?;
    sampler.client_mut(0).restore_state_chunked(&states[0], MESH_SMOKE_CHUNK)?;
    for (s, ep) in endpoints.iter().enumerate() {
        let again = RemoteClient::connect_endpoint(ep)?.checkpoint_bytes()?;
        let twin_bytes = ServiceState::capture(&twins[s])?.encode();
        ensure!(
            again == twin_bytes,
            "server {s} ({ep}) state changed across the chunked restore round-trip"
        );
    }
    eprintln!(
        "[smoke] mesh OK: {n} servers, {} items, {rounds} batches {batches:?}, \
         per-server checkpoints byte-identical ({state_bytes} bytes total, \
         {MESH_SMOKE_CHUNK}-byte chunks)",
        per_server * n
    );

    // Phase 4: exact per-server accounting against the Stats RPC.
    for (s, ep) in endpoints.iter().enumerate() {
        let stats = RemoteClient::connect_endpoint(ep)?.stats()?;
        let replay = &stats[0];
        ensure!(
            replay.stats.inserts == per_server,
            "server {s}: {} inserts recorded, its writer appended {per_server}",
            replay.stats.inserts
        );
        ensure!(
            replay.stats.sample_batches == batches[s],
            "server {s}: {} batches recorded, the mesh drew {}",
            replay.stats.sample_batches,
            batches[s]
        );
        ensure!(
            replay.stats.sampled_items == 16 * batches[s],
            "server {s}: sampled-items accounting off: {} != 16·{}",
            replay.stats.sampled_items,
            batches[s]
        );
        ensure!(
            replay.stats.priority_updates == 16 * batches[s],
            "server {s}: priority-update accounting off: {} != 16·{}",
            replay.stats.priority_updates,
            batches[s]
        );
        // The N-step auxiliary table may hold a partial window tail per
        // writer (flushed only at an episode boundary).
        for t in stats.iter().skip(1) {
            ensure!(
                t.stats.inserts <= per_server && t.stats.inserts + 2 >= per_server,
                "server {s} table `{}`: {} inserts for {per_server} appended steps",
                t.name,
                t.stats.inserts
            );
        }
    }

    drop(sampler);
    for ep in &endpoints {
        RemoteClient::connect_endpoint(ep)?.shutdown()?;
    }
    println!(
        "mesh-smoke OK: {n} servers, {} inserts, {} batches, byte-identical per-server \
         checkpoints (chunked), lockstep two-level sampling, exact per-server accounting",
        per_server * n,
        batches.iter().sum::<usize>()
    );
    Ok(())
}

const CHAOS_SMOKE_FLAGS: &[&str] =
    &["dir", "seed", "steps-per-writer", "batches-per-sampler", "tcp"];

/// Bounded retry for client connects that race a chaos fault (the
/// proxy may reset the very `Hello` that opens a connection).
fn retry_connect<T>(what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
    let mut last = None;
    for _ in 0..50 {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    Err(last.expect("at least one attempt ran").context(format!("{what} kept failing")))
}

/// One replay server for the chaos drill, served from a background
/// thread so the drill can hard-stop and restart it in-process.
struct ChaosServer {
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: std::thread::JoinHandle<Result<()>>,
}

impl ChaosServer {
    /// Bind `endpoint` and serve in the background; returns the
    /// RESOLVED endpoint (a TCP `:0` bind lands on a concrete port,
    /// which the restart drill must rebind exactly).
    fn start(
        cfg: &TrainConfig,
        endpoint: &Endpoint,
        state: Option<&ServiceState>,
    ) -> Result<(Self, Endpoint)> {
        let service = Arc::new(build_service(cfg, SMOKE_OBS, SMOKE_ACT)?);
        if let Some(s) = state {
            service.restore(s)?;
        }
        let server = ReplayServer::bind_endpoint(Arc::clone(&service), endpoint, 0)?
            .expect_dims(SMOKE_OBS, SMOKE_ACT)
            .with_drain_deadline(std::time::Duration::from_millis(500));
        let resolved = server.endpoint();
        let stop = server.stop_handle();
        let thread = std::thread::spawn(move || server.serve());
        Ok((Self { stop, thread }, resolved))
    }

    /// Ask the accept loop to stop and wait for it. Phase B uses this
    /// as the `kill -9` stand-in: the sessions, the reply caches, and
    /// the socket all die with the serving thread.
    fn stop(self) -> Result<()> {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        self.thread
            .join()
            .map_err(|_| anyhow!("replay server thread panicked"))?
    }
}

/// Fail with the first differing offset when two checkpoints diverge.
fn ensure_checkpoints_match(stage: &str, remote: &[u8], local: &[u8]) -> Result<()> {
    if remote == local {
        return Ok(());
    }
    let first_diff = remote
        .iter()
        .zip(local)
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| remote.len().min(local.len()));
    bail!(
        "{stage}: remote checkpoint differs from the in-process twin: {} vs {} bytes, \
         first difference at offset {first_diff}",
        remote.len(),
        local.len()
    )
}

/// `pal chaos-smoke`: the self-contained fault-tolerance restart drill
/// (the CI gate wired up by tools/chaos_smoke.sh). Everything runs in
/// this process — a real [`ReplayServer`] on a private socket, a
/// seeded [`ChaosProxy`] in front of it, and an unfaulted in-process
/// twin service mirroring every operation — so the drill needs no
/// orchestration and its verdict is exact:
///
/// * phase A — 3 concurrent writers + 2 concurrent samplers soak the
///   server THROUGH the proxy (delays, shredded writes, seeded
///   resets); every reconnect must resume its session, so the
///   checkpoint afterwards is byte-identical to the twin's;
/// * phase B — the server is hard-stopped mid-outage (the `kill -9`
///   stand-in) while writers keep appending into their spill queues; a
///   fresh server restores the phase-A checkpoint and every spilled
///   step lands exactly once;
/// * phase C — pipelined samplers re-arm against the restarted server
///   in lockstep with the twin (prefetch + priority updates under
///   faults);
/// * phase D — a writer with a tiny spill cap rides out a full outage:
///   overflow drops oldest-first and the drops are accounted in every
///   table's `steps_dropped` once the link heals.
///
/// The final checkpoint must be byte-identical to the twin's and the
/// final Stats must account for every client-side operation exactly.
fn cmd_chaos_smoke(a: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    a.check_known(CHAOS_SMOKE_FLAGS)?;
    let dir: std::path::PathBuf = match a.get("dir") {
        Some(d) => d.into(),
        None => std::env::temp_dir().join(format!("pal_chaos_smoke_{}", std::process::id())),
    };
    let seed: u64 = a.parse_or("seed", 0xC4A0_5EED)?;
    let steps_per_writer: usize = a.parse_or("steps-per-writer", 320)?;
    let batches_per_sampler: usize = a.parse_or("batches-per-sampler", 30)?;
    ensure!(
        steps_per_writer >= 128 && steps_per_writer % 32 == 0,
        "--steps-per-writer must be a multiple of 32 (the episode length) and >= 128"
    );
    ensure!(batches_per_sampler >= 1, "--batches-per-sampler must be >= 1");
    std::fs::create_dir_all(&dir)?;
    // `--tcp` runs the identical drill over loopback TCP (ephemeral
    // ports, resolved at bind): the chaos determinism contract and
    // every byte-identity assertion are transport-independent.
    let tcp = a.flag("tcp");
    let (server_bind, proxy_bind) = if tcp {
        (Endpoint::tcp("127.0.0.1:0")?, Endpoint::tcp("127.0.0.1:0")?)
    } else {
        (Endpoint::from(dir.join("server.sock")), Endpoint::from(dir.join("proxy.sock")))
    };

    // Unlimited limiter: admission never stalls, so the concurrent
    // phases' stall counters are deterministically zero and the twin
    // comparison stays byte-exact.
    let mut cfg = smoke_config(a)?;
    cfg.rate_limit = RateLimitSpec::Unlimited;
    let warmup = cfg.warmup_steps;

    let policy = ConnectionPolicy {
        rpc_timeout: Duration::from_secs(10),
        backoff: BackoffPolicy::default().with_deadline(Duration::from_secs(20)),
    };
    let chaos = ChaosConfig {
        seed,
        delay_chance: 0.02,
        max_delay: Duration::from_millis(2),
        shred_chance: 0.05,
        reset_chance: 0.01,
        max_resets: 4,
    };
    let (server, server_ep) = ChaosServer::start(&cfg, &server_bind, None)?;
    let proxy = ChaosProxy::start_endpoints(&server_ep, &proxy_bind, chaos)?;
    let proxy_ep = proxy.listen_endpoint().clone();
    eprintln!("[chaos] server on {server_ep} behind seeded proxy on {proxy_ep} (seed {seed:#x})");

    // ---- Phase A: concurrent soak through the faulted link ---------
    let soak_batches = AtomicU64::new(0);
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for actor in 0..3usize {
            let proxy_ep = &proxy_ep;
            let policy = policy.clone();
            handles.push(s.spawn(move || -> Result<()> {
                let w = retry_connect("soak writer connect", || {
                    RemoteWriter::connect_endpoint_with(proxy_ep, actor as u64, policy.clone())
                })?;
                let mut w = w.with_batch(REMOTE_SMOKE_BATCH);
                for i in 0..steps_per_writer {
                    let mut spins = 0u32;
                    while w.throttled()? {
                        spins += 1;
                        ensure!(spins < 60_000, "soak writer throttled >60s");
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    w.append(smoke_step(actor * 1_000_000 + i))?;
                }
                let mut spins = 0u32;
                while w.flush()? > 0 {
                    spins += 1;
                    ensure!(spins < 60_000, "soak writer could not drain");
                    std::thread::sleep(Duration::from_millis(1));
                }
                ensure!(
                    w.steps_dropped() == 0,
                    "soak writer dropped steps without a spill overflow"
                );
                Ok(())
            }));
        }
        for sidx in 0..2u64 {
            let proxy_ep = &proxy_ep;
            let server_ep = &server_ep;
            let policy = policy.clone();
            let soak_batches = &soak_batches;
            handles.push(s.spawn(move || -> Result<()> {
                // Gate on warmup over the DIRECT endpoint (`Stats`
                // never touches table counters), so the faulted sampler
                // never sees NotEnoughData — keeping outcomes, and
                // therefore counters, deterministic.
                let mut direct = RemoteClient::connect_endpoint(server_ep)?;
                let mut spins = 0u32;
                while direct.stats()?[0].len < warmup as u64 {
                    spins += 1;
                    ensure!(spins < 60_000, "replay table never reached warmup");
                    std::thread::sleep(Duration::from_millis(1));
                }
                let mut smp = retry_connect("soak sampler connect", || {
                    RemoteSampler::connect_default_endpoint_with(
                        proxy_ep,
                        0xC4A0_0000 + sidx,
                        policy.clone(),
                    )
                })?;
                let mut rng = Rng::new(1); // sampling uses the server-side RNG
                let mut out = SampleBatch::default();
                for b in 0..batches_per_sampler {
                    match smp.try_sample(16, &mut rng, &mut out)? {
                        SampleOutcome::Sampled => ensure!(
                            out.priorities.iter().all(|&p| p > 0.0),
                            "sampled a zero-priority item through the proxy"
                        ),
                        other => bail!("soak sampler {sidx} stalled at batch {b}: {other:?}"),
                    }
                    soak_batches.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        for r in results {
            r.map_err(|_| anyhow!("chaos soak thread panicked"))??;
        }
        Ok(())
    })?;

    // Twin mirror of phase A (unfaulted, in-process): the actor ids
    // land on distinct shards, so per-shard insertion order — and the
    // checkpoint bytes — are independent of thread interleaving.
    let twin = build_service(&cfg, SMOKE_OBS, SMOKE_ACT)?;
    for actor in 0..3usize {
        let mut tw = twin.writer(actor);
        for i in 0..steps_per_writer {
            ensure!(!tw.throttled(), "twin writer throttled under an unlimited limiter");
            tw.append(smoke_step(actor * 1_000_000 + i));
        }
    }
    {
        let ts = twin.default_sampler();
        let mut rng = Rng::new(0xA11CE);
        let mut out = SampleBatch::default();
        for b in 0..2 * batches_per_sampler {
            ensure!(
                ts.try_sample(16, &mut rng, &mut out) == SampleOutcome::Sampled,
                "twin sampler stalled at batch {b}"
            );
        }
    }
    let mid_bytes = RemoteClient::connect_endpoint(&server_ep)?.checkpoint_bytes()?;
    ensure_checkpoints_match(
        "after the chaos soak",
        &mid_bytes,
        &ServiceState::capture(&twin)?.encode(),
    )?;
    let soak_batches = soak_batches.load(Ordering::Relaxed);
    eprintln!(
        "[chaos] phase A OK: {} appends + {soak_batches} sampled batches through the proxy, \
         checkpoint byte-identical ({} bytes), {} proxy reset(s) so far",
        3 * steps_per_writer,
        mid_bytes.len(),
        proxy.resets_injected()
    );

    // ---- Phase B: hard-kill the server mid-outage, restart it from
    // the checkpoint, deliver every spilled step exactly once --------
    let mut writers_b = Vec::new();
    for a_id in 0..3u64 {
        let w = retry_connect("outage writer connect", || {
            RemoteWriter::connect_endpoint_with(&proxy_ep, 10 + a_id, policy.clone())
        })?;
        writers_b.push(w.with_batch(REMOTE_SMOKE_BATCH));
    }
    proxy.set_blackhole(true);
    proxy.kill_connections();
    server.stop()?;
    ensure!(
        RemoteClient::connect_endpoint(&server_ep).is_err(),
        "server endpoint still answers after the kill"
    );
    for (a_idx, w) in writers_b.iter_mut().enumerate() {
        for i in 0..steps_per_writer {
            ensure!(
                !w.throttled()?,
                "writer must keep accepting steps during an outage (spill), not block"
            );
            w.append(smoke_step((10 + a_idx) * 1_000_000 + i))?;
        }
        ensure!(
            w.pending_len() == steps_per_writer && w.steps_dropped() == 0,
            "outage writer spilled wrong: {} pending, {} dropped (want {steps_per_writer} / 0)",
            w.pending_len(),
            w.steps_dropped()
        );
    }
    let restored = ServiceState::decode(&mid_bytes)?;
    let (server, _) = ChaosServer::start(&cfg, &server_ep, Some(&restored))?;
    proxy.set_blackhole(false);
    for w in &mut writers_b {
        let mut spins = 0u32;
        while w.flush()? > 0 {
            spins += 1;
            ensure!(spins < 60_000, "outage writer could not drain after the restart");
            std::thread::sleep(Duration::from_millis(1));
        }
        ensure!(w.reconnects() >= 1, "outage writer never reconnected");
        ensure!(w.steps_dropped() == 0, "outage writer dropped steps below its spill cap");
    }
    drop(writers_b);
    for a_idx in 0..3usize {
        let mut tw = twin.writer(10 + a_idx);
        for i in 0..steps_per_writer {
            tw.append(smoke_step((10 + a_idx) * 1_000_000 + i));
        }
    }
    ensure_checkpoints_match(
        "after the kill/restart drill",
        &RemoteClient::connect_endpoint(&server_ep)?.checkpoint_bytes()?,
        &ServiceState::capture(&twin)?.encode(),
    )?;
    eprintln!(
        "[chaos] phase B OK: server killed and restarted from its checkpoint, {} spilled \
         steps delivered exactly once",
        3 * steps_per_writer
    );

    // ---- Phase C: pipelined samplers re-arm against the restarted
    // server, in lockstep with the twin ------------------------------
    let mut c_grants = 0u64;
    let mut c_updates = 0u64;
    for s_seed in [seed ^ 0x51, seed ^ 0x52] {
        let smp = retry_connect("prefetch sampler connect", || {
            RemoteSampler::connect_default_endpoint_with(&proxy_ep, s_seed, policy.clone())
        })?;
        let mut smp = smp.with_prefetch(true);
        let mut local_rng = Rng::new(s_seed);
        let (granted, updated) =
            prefetch_lockstep_drive(&mut smp, &twin.default_sampler(), &mut local_rng, 16)?;
        c_grants += granted;
        c_updates += updated;
    }
    eprintln!("[chaos] phase C OK: {c_grants} prefetched batches re-armed after the restart");

    // ---- Phase D: spill overflow under a full outage ---------------
    let w7 = retry_connect("spill writer connect", || {
        RemoteWriter::connect_endpoint_with(&proxy_ep, 7, policy.clone())
    })?;
    let mut w7 = w7.with_batch(4).with_spill_cap(8);
    proxy.set_blackhole(true);
    proxy.kill_connections();
    for i in 0..40usize {
        ensure!(!w7.throttled()?, "spill writer must not block during the outage");
        w7.append(smoke_step(7_000_000 + i))?;
    }
    ensure!(
        w7.steps_dropped() == 32 && w7.pending_len() == 8,
        "spill overflow accounting wrong: {} dropped, {} pending (want 32 / 8)",
        w7.steps_dropped(),
        w7.pending_len()
    );
    proxy.set_blackhole(false);
    let mut spins = 0u32;
    while w7.flush()? > 0 {
        spins += 1;
        ensure!(spins < 60_000, "spill writer could not drain after the outage");
        std::thread::sleep(Duration::from_millis(1));
    }
    ensure!(w7.reconnects() >= 1, "spill writer never reconnected");
    // Twin mirror: the first failed flush pinned steps 0..4 in flight
    // (they survive the overflow), the spill tail 36..40 survives by
    // recency, and the 32 steps between dropped — which the server
    // accounts into every table's steps_dropped on delivery. The twin
    // writer stays alive through the final capture, mirroring the
    // still-open remote session (partial N-step windows stay pending
    // on both sides).
    let mut tw7 = twin.writer(7);
    for i in (0..4usize).chain(36..40) {
        tw7.append(smoke_step(7_000_000 + i));
    }
    for t in twin.tables() {
        t.add_steps_dropped(32);
    }
    let final_remote = RemoteClient::connect_endpoint(&server_ep)?.checkpoint_bytes()?;
    ensure_checkpoints_match(
        "after the spill-overflow drill",
        &final_remote,
        &ServiceState::capture(&twin)?.encode(),
    )?;
    drop(w7);

    // ---- Exact end-to-end accounting over the direct endpoint ------
    let stats = RemoteClient::connect_endpoint(&server_ep)?.stats()?;
    ensure!(!stats.is_empty(), "server reports no tables after the drill");
    let total_steps = 6 * steps_per_writer + 8;
    let replay = &stats[0];
    ensure!(
        replay.stats.inserts == total_steps,
        "insert accounting off: {} recorded, clients delivered {total_steps}",
        replay.stats.inserts
    );
    let total_batches = soak_batches + c_grants;
    ensure!(
        replay.stats.sample_batches as u64 == total_batches,
        "batch accounting off: {} recorded, clients drew {total_batches}",
        replay.stats.sample_batches
    );
    ensure!(
        replay.stats.sampled_items as u64 == 16 * total_batches,
        "sampled-items accounting off: {} != 16·{total_batches}",
        replay.stats.sampled_items
    );
    ensure!(
        replay.stats.priority_updates as u64 == 16 * c_updates,
        "priority-update accounting off: {} != 16·{c_updates}",
        replay.stats.priority_updates
    );
    for t in &stats {
        ensure!(
            t.stats.steps_dropped == 32,
            "table `{}`: steps_dropped {} != 32",
            t.name,
            t.stats.steps_dropped
        );
        ensure!(
            t.stats.insert_stalls == 0 && t.stats.sample_stalls == 0,
            "table `{}` stalled under an unlimited limiter",
            t.name
        );
    }
    let resets = proxy.resets_injected();
    ensure!(resets >= 1, "the chaos proxy never injected a reset");

    RemoteClient::connect_endpoint(&server_ep)?.shutdown()?;
    server.stop()?;
    drop(proxy);
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "chaos-smoke OK ({}): {total_steps} steps exactly once across {resets} proxy resets \
         and one server restart, 32 overflow drops accounted, final checkpoint byte-identical \
         ({} bytes)",
        if tcp { "tcp" } else { "uds" },
        final_remote.len()
    );
    Ok(())
}

const MESH_CHAOS_FLAGS: &[&str] = &["dir", "items", "capacity", "shards"];

/// The drill's direct (proxy-bypassing) read of one server's
/// learner-table Stats; connect, read, drop — so the probe never
/// leaves a connection for a later kill to strand.
fn mesh_replay_stats(ep: &Endpoint) -> Result<TableInfo> {
    let stats = RemoteClient::connect_endpoint(ep)?.stats()?;
    ensure!(!stats.is_empty(), "server {ep} reports no tables");
    Ok(stats[0].clone())
}

/// Drive `rounds` sample + priority-update rounds against the mesh,
/// tallying which server each batch came from (global index ÷ stride —
/// a whole batch always comes from one server).
fn mesh_drive(
    sampler: &mut MeshSampler,
    stride: usize,
    rounds: usize,
    batches: &mut [u64],
    updates: &mut [u64],
) -> Result<()> {
    let mut unused = Rng::new(1); // mesh sampling draws server-side
    let mut out = SampleBatch::default();
    for round in 0..rounds {
        match sampler.try_sample(16, &mut unused, &mut out)? {
            SampleOutcome::Sampled => {}
            other => bail!("mesh sampler stalled at round {round}: {other:?}"),
        }
        ensure!(!out.indices.is_empty(), "a granted batch came back empty");
        let sel = out.indices[0] / stride;
        ensure!(
            out.indices.iter().all(|&i| i / stride == sel),
            "batch at round {round} mixed servers"
        );
        // Priorities are a pure function of (round, slot): the tallies,
        // not the values, are what the drill accounts.
        let tds: Vec<f32> = (0..out.indices.len())
            .map(|j| ((round * 13 + j) % 91) as f32 * 0.1 + 0.05)
            .collect();
        sampler.update_priorities(&out.indices, &tds)?;
        batches[sel] += 1;
        updates[sel] += 1;
    }
    Ok(())
}

/// `pal mesh-chaos-smoke`: the elastic-mesh kill-and-rejoin drill (the
/// CI gate wired up by tools/chaos_smoke.sh). A 3-server replay mesh
/// runs in this process, each server behind a pass-through proxy whose
/// only job is the kill switch (severing the proxied connections makes
/// a server stop look like `kill -9` to every attached client):
///
/// * phase A — affinity writers and a mass-proportional sampler soak
///   the healthy mesh; per-server Stats must account for every append,
///   batch and priority update exactly;
/// * phase B — server 1 is hard-killed mid-run. The sampler must keep
///   granting batches from the survivors, walk the victim's health to
///   Down, and count its degraded draws; the stranded writer must ride
///   its spill queue to saturation, fail over to a survivor carrying
///   every unacked step, and drop nothing;
/// * phase C — server 1 restarts from its pre-kill checkpoint. The
///   sampler's seeded probe schedule must mark it Up and resume
///   drawing from it (a counted rejoin); the displaced writer must
///   fail back home once its queue idles;
/// * phase D — server 2 live-drains into server 0 over the chunked
///   state stream and exits clean; the migrated rows must show up in
///   the receiver's tables.
///
/// The final mesh-wide Stats deltas must account for every client-side
/// operation exactly — inserts conserved across failover, restart AND
/// the drain handoff, with zero dropped steps anywhere.
fn cmd_mesh_chaos_smoke(a: &Args) -> Result<()> {
    use std::time::Duration;

    a.check_known(MESH_CHAOS_FLAGS)?;
    let dir: std::path::PathBuf = match a.get("dir") {
        Some(d) => d.into(),
        None => std::env::temp_dir().join(format!("pal_mesh_chaos_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir)?;
    let items: usize = a.parse_or("items", 960)?;
    let n = 3usize;
    let per = items / n;
    let mut cfg = smoke_config(a)?;
    cfg.rate_limit = RateLimitSpec::Unlimited;
    ensure!(
        per >= cfg.warmup_steps * 2,
        "--items {items} too small for warmup {} across {n} servers",
        cfg.warmup_steps
    );
    ensure!(
        cfg.buffer_capacity >= 2 * items,
        "--capacity {} too small to absorb the drain handoff without evictions (need >= {})",
        cfg.buffer_capacity,
        2 * items
    );

    let mut servers: Vec<Option<ChaosServer>> = Vec::new();
    let mut server_eps: Vec<Endpoint> = Vec::new();
    let mut proxies = Vec::new();
    let mut mesh_eps: Vec<Endpoint> = Vec::new();
    for s in 0..n {
        let bind = Endpoint::from(dir.join(format!("server{s}.sock")));
        let (srv, ep) = ChaosServer::start(&cfg, &bind, None)?;
        let proxy_bind = Endpoint::from(dir.join(format!("proxy{s}.sock")));
        let proxy = ChaosProxy::start_endpoints(&ep, &proxy_bind, ChaosConfig::default())?;
        mesh_eps.push(proxy.listen_endpoint().clone());
        servers.push(Some(srv));
        server_eps.push(ep);
        proxies.push(proxy);
    }
    let policy = ConnectionPolicy {
        rpc_timeout: Duration::from_secs(10),
        backoff: BackoffPolicy::default().with_deadline(Duration::from_secs(5)),
    };

    // ---- Phase A: soak the healthy mesh ----------------------------
    let mut writers = Vec::new();
    for actor in 0..n {
        let mut w = MeshWriter::connect(&mesh_eps, actor as u64, policy.clone())?
            .with_batch(REMOTE_SMOKE_BATCH)
            .with_spill_cap(2 * REMOTE_SMOKE_BATCH);
        ensure!(w.server() == actor, "actor {actor} routed to server {}", w.server());
        for i in 0..per {
            w.append(smoke_step(actor * 1_000_000 + i))?;
        }
        ensure!(w.flush()? == 0, "mesh writer {actor} could not drain its batch tail");
        writers.push(w);
    }
    let mut sampler = MeshSampler::connect_default(&mesh_eps, 0x4D43_5EED, policy.clone())?
        .with_mass_ttl(Duration::from_millis(5));
    let stride = sampler.stride();
    let mut batches = vec![0u64; n];
    let mut updates = vec![0u64; n];
    let rounds_a = 48usize;
    mesh_drive(&mut sampler, stride, rounds_a, &mut batches, &mut updates)?;
    ensure!(
        batches.iter().all(|&b| b > 0),
        "the mass-proportional pick never chose some server (batches {batches:?})"
    );
    for (s, ep) in server_eps.iter().enumerate() {
        let t = mesh_replay_stats(ep)?;
        ensure!(
            t.stats.inserts == per,
            "server {s}: {} inserts after the soak, its writer appended {per}",
            t.stats.inserts
        );
        ensure!(
            t.stats.sample_batches as u64 == batches[s]
                && t.stats.sampled_items as u64 == 16 * batches[s]
                && t.stats.priority_updates as u64 == 16 * updates[s],
            "server {s}: soak accounting off (batches {}, items {}, updates {})",
            t.stats.sample_batches,
            t.stats.sampled_items,
            t.stats.priority_updates
        );
    }
    eprintln!(
        "[mesh-chaos] phase A OK: {} appends, {rounds_a} batches {batches:?} across {n} servers",
        n * per
    );

    // ---- Phase B: hard-kill server 1 mid-run -----------------------
    let victim = 1usize;
    let ckpt = RemoteClient::connect_endpoint(&server_eps[victim])?.checkpoint_bytes()?;
    proxies[victim].set_blackhole(true);
    proxies[victim].kill_connections();
    servers[victim].take().expect("victim still running").stop()?;
    ensure!(
        RemoteClient::connect_endpoint(&server_eps[victim]).is_err(),
        "victim endpoint still answers after the kill"
    );

    // The stranded writer (actor 1, homed on the victim) keeps
    // appending: the first batches spill locally, and once the queue
    // saturates its cap the writer must fail over to a survivor
    // carrying every unacked step — no drops, nothing blocked.
    let spill_steps = 3 * REMOTE_SMOKE_BATCH;
    for i in 0..spill_steps {
        writers[victim].append(smoke_step(victim * 1_000_000 + per + i))?;
    }
    ensure!(
        writers[victim].failovers() >= 1 && writers[victim].server() != victim,
        "stranded writer never failed over (still on server {})",
        writers[victim].server()
    );
    ensure!(writers[victim].flush()? == 0, "failed-over writer could not drain");
    ensure!(
        writers[victim].steps_dropped() == 0,
        "failover dropped {} steps below the spill cap",
        writers[victim].steps_dropped()
    );

    // Survivor sampling: every draw must still grant, renormalized
    // away from the victim, and the membership ladder must walk it to
    // Down on the sampler's (TTL-paced) failed probes.
    let batches_a = batches.clone();
    mesh_drive(&mut sampler, stride, 32, &mut batches, &mut updates)?;
    ensure!(batches[victim] == batches_a[victim], "a batch was drawn from the dead server");
    let mut spins = 0u32;
    while sampler.health(victim) != HealthState::Down {
        spins += 1;
        ensure!(
            spins < 2_000,
            "victim never reached Down (health {:?})",
            sampler.health(victim)
        );
        std::thread::sleep(Duration::from_millis(2));
        mesh_drive(&mut sampler, stride, 1, &mut batches, &mut updates)?;
    }
    let c = sampler.counters();
    ensure!(
        c.downs >= 1 && c.degraded_draws >= 1,
        "degraded-mode counters never moved: {c:?}"
    );
    let survivor_inserts: usize = (0..n)
        .filter(|&s| s != victim)
        .map(|s| mesh_replay_stats(&server_eps[s]).map(|t| t.stats.inserts))
        .sum::<Result<usize>>()?;
    ensure!(
        survivor_inserts == 2 * per + spill_steps,
        "phase B conservation off: survivors hold {survivor_inserts} inserts, expected {} \
         ({} soaked + {spill_steps} failed over)",
        2 * per + spill_steps,
        2 * per
    );
    eprintln!(
        "[mesh-chaos] phase B OK: server {victim} killed — sampler renormalized ({} degraded \
         draws so far), writer failed over to server {} with its whole spill queue",
        c.degraded_draws,
        writers[victim].server()
    );

    // ---- Phase C: restart the victim from its checkpoint -----------
    let restored = ServiceState::decode(&ckpt)?;
    let (reborn, _) = ChaosServer::start(&cfg, &server_eps[victim], Some(&restored))?;
    servers[victim] = Some(reborn);
    proxies[victim].set_blackhole(false);
    // Rejoin: the next due probe redials, the health ladder climbs
    // back to Up, and the mass draw starts landing on the reborn
    // server again.
    let mut spins = 0u32;
    while sampler.health(victim) != HealthState::Up || batches[victim] == batches_a[victim] {
        spins += 1;
        ensure!(
            spins < 5_000,
            "server {victim} never rejoined (health {:?})",
            sampler.health(victim)
        );
        std::thread::sleep(Duration::from_millis(2));
        mesh_drive(&mut sampler, stride, 1, &mut batches, &mut updates)?;
    }
    ensure!(sampler.counters().rejoins >= 1, "rejoin not counted: {:?}", sampler.counters());

    // Writer fail-back: with its home server back and its queue idle,
    // the displaced writer's paced route probe must carry it home
    // (within ~2 probe windows of ops, far under this bound).
    let mut extra = 0usize;
    while writers[victim].server() != victim {
        ensure!(extra < 512, "displaced writer never failed back home");
        writers[victim].append(smoke_step(victim * 1_000_000 + per + spill_steps + extra))?;
        extra += 1;
    }
    ensure!(writers[victim].flush()? == 0, "displaced writer could not drain");
    // And appends stay home from here on: land one more batch on the
    // reborn server so its post-restore insert delta is visible.
    for j in 0..REMOTE_SMOKE_BATCH {
        writers[victim]
            .append(smoke_step(victim * 1_000_000 + per + spill_steps + extra + j))?;
    }
    ensure!(writers[victim].flush()? == 0, "failed-back writer could not drain");
    ensure!(writers[victim].server() == victim, "writer bounced off its home again");
    let t1 = mesh_replay_stats(&server_eps[victim])?;
    ensure!(
        t1.stats.inserts == per + REMOTE_SMOKE_BATCH,
        "reborn server {victim}: {} inserts (checkpoint held {per}, {REMOTE_SMOKE_BATCH} new)",
        t1.stats.inserts
    );
    ensure!(
        t1.stats.sample_batches as u64 == batches[victim]
            && t1.stats.priority_updates as u64 == 16 * updates[victim],
        "reborn server {victim}: sampling deltas off (batches {}, updates {})",
        t1.stats.sample_batches,
        t1.stats.priority_updates
    );
    eprintln!(
        "[mesh-chaos] phase C OK: server {victim} restarted from its checkpoint, rejoined the \
         draw, writer failed back home after {extra} displaced append(s)"
    );

    // ---- Phase D: live drain — server 2 leaves the mesh ------------
    let donor = 2usize;
    let receiver = 0usize;
    for (actor, w) in writers.iter_mut().enumerate() {
        ensure!(w.flush()? == 0, "writer {actor} could not quiesce before the drain");
    }
    drop(writers);
    let before_r = mesh_replay_stats(&server_eps[receiver])?;
    let before_d = mesh_replay_stats(&server_eps[donor])?;
    RemoteClient::connect_endpoint(&server_eps[donor])?
        .drain(&[server_eps[receiver].to_string()], MESH_SMOKE_CHUNK as u32)?;
    // The Drain reply means the handoff landed; the donor's serve loop
    // is already stopping (its stop flag is set like a Shutdown's).
    servers[donor].take().expect("donor still running").stop()?;
    ensure!(
        RemoteClient::connect_endpoint(&server_eps[donor]).is_err(),
        "donor endpoint still answers after the drain"
    );
    let after_r = mesh_replay_stats(&server_eps[receiver])?;
    ensure!(
        after_r.len == before_r.len + before_d.len,
        "drain lost rows: receiver holds {} (had {}, donor sent {})",
        after_r.len,
        before_r.len,
        before_d.len
    );
    // Post-drain draws must renormalize away from the drained slot
    // (its zero mass advert while draining, then its dead socket).
    let batches_d = batches.clone();
    mesh_drive(&mut sampler, stride, 24, &mut batches, &mut updates)?;
    ensure!(batches[donor] == batches_d[donor], "a batch was drawn from the drained server");
    eprintln!(
        "[mesh-chaos] phase D OK: server {donor} drained {} rows into server {receiver} and \
         left the mesh",
        before_d.len
    );

    // ---- Final mesh-wide accounting --------------------------------
    // Every append the drill made sits on some live server exactly
    // once — conserved across failover, restart and the drain handoff
    // — and every sampled batch and priority update is on the books of
    // the server that granted it.
    let total_appends = n * per + spill_steps + extra + REMOTE_SMOKE_BATCH;
    let live = [receiver, victim];
    let mut total_inserts = 0usize;
    let mut total_batches = 0u64;
    let mut total_items = 0u64;
    let mut total_updates = 0u64;
    for &s in &live {
        let t = mesh_replay_stats(&server_eps[s])?;
        total_inserts += t.stats.inserts;
        total_batches += t.stats.sample_batches as u64;
        total_items += t.stats.sampled_items as u64;
        total_updates += t.stats.priority_updates as u64;
        ensure!(
            t.stats.steps_dropped == 0,
            "server {s} reports {} dropped steps; the drill drops nothing",
            t.stats.steps_dropped
        );
    }
    ensure!(
        total_inserts == total_appends,
        "mesh-wide insert conservation failed: {total_inserts} held on the live servers, \
         clients appended {total_appends}"
    );
    let live_batches = batches[receiver] + batches[victim];
    let live_updates = updates[receiver] + updates[victim];
    ensure!(
        total_batches == live_batches && total_items == 16 * live_batches,
        "mesh-wide sampling accounting off: {total_batches} batches / {total_items} items \
         recorded vs {live_batches} client draws"
    );
    ensure!(
        total_updates == 16 * live_updates,
        "mesh-wide priority-update accounting off: {total_updates} != 16·{live_updates}"
    );

    let counters = sampler.counters();
    drop(sampler);
    for &s in &live {
        RemoteClient::connect_endpoint(&server_eps[s])?.shutdown()?;
    }
    for srv in servers.into_iter().flatten() {
        srv.stop()?;
    }
    drop(proxies);
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "mesh-chaos-smoke OK: kill, failover, rejoin and live drain on a {n}-server mesh — \
         {total_appends} appends and {live_batches} batches accounted exactly \
         ({} degraded draws, {} down transition(s), {} rejoin(s), {} mass probes)",
        counters.degraded_draws, counters.downs, counters.rejoins, counters.mass_rpcs
    );
    Ok(())
}

fn cmd_dse(a: &Args) -> Result<()> {
    let cores: usize = a.parse_or("cores", 8)?;
    let ratio: f64 = a.parse_or("update-interval", 1.0)?;
    let algo = a.str_or("algo", "dqn");
    let env = a.str_or("env", "CartPole-v1");
    let mut profile = dse::CostProfile::representative(&algo, &env);
    // Replay-service rate limiter in the modeled pipeline (σ samples
    // per insert; 0 = no limiter).
    profile.samples_per_insert = a.parse_or("rate-limit", 0.0)?;
    let plan = dse::explore(&profile, cores, ratio);
    println!("{}", dse::render_curves(&profile, cores));
    println!(
        "chosen split for M={cores}, ratio={ratio}: {} actors + {} learners \
         (collect {:.0}/s vs consume {:.0}/s)",
        plan.actors, plan.learners, plan.collect_throughput, plan.consume_throughput
    );
    if profile.samples_per_insert > 0.0 {
        let (actor_stall, learner_stall) =
            profile.limiter_stalls(plan.actors, plan.learners, cores);
        println!(
            "rate limiter σ={}: stall terms at this split — actors {:.1}%, \
             learners {:.1}% of free-run throughput",
            profile.samples_per_insert,
            actor_stall * 100.0,
            learner_stall * 100.0,
        );
    }
    // Replay-shard dimension of the design space.
    let candidates = a.usize_list("shards", &[1, 2, 4, 8, 16])?;
    let sweep = profile.shard_sweep(cores, ratio, &candidates);
    println!("\nshard sweep (best balanced throughput per S):");
    for &(s, tput) in &sweep {
        println!("  S={s:2}  {tput:10.0} steps/s");
    }
    let (best_s, best_t) = dse::CostProfile::pick_best_shards(&sweep);
    println!("planner's shard choice: S={best_s} ({best_t:.0} steps/s)");
    Ok(())
}

fn main() -> Result<()> {
    let a = Args::from_env()?;
    let cmd = a.positional.first().map(String::as_str);
    match cmd {
        Some("train") => cmd_train(&a),
        Some("serve") => cmd_serve(&a),
        Some("envs") => {
            cmd_envs();
            Ok(())
        }
        Some("info") => cmd_info(&a),
        Some("buffer-bench") => cmd_buffer_bench(&a),
        Some("state-smoke") => cmd_state_smoke(&a),
        Some("remote-smoke") => cmd_remote_smoke(&a),
        Some("tenant-smoke") => cmd_tenant_smoke(&a),
        Some("mesh-smoke") => cmd_mesh_smoke(&a),
        Some("chaos-smoke") => cmd_chaos_smoke(&a),
        Some("mesh-chaos-smoke") => cmd_mesh_chaos_smoke(&a),
        Some("drain") => cmd_drain(&a),
        Some("dse") => cmd_dse(&a),
        Some(other) => bail!("unknown subcommand `{other}` (try `pal` for usage)"),
        None => usage(),
    }
}
