//! Compile-time stub of the `xla` (PJRT) bindings.
//!
//! The container has neither crates.io access nor an XLA/PJRT shared
//! library, so this vendored crate provides the exact API surface
//! `pal_rl::runtime` compiles against. [`Literal`] is fully functional
//! (it is plain host data); everything that would require a real PJRT
//! runtime — client creation, compilation, execution — returns a clean
//! [`XlaError`] at runtime. All integration tests that execute compiled
//! graphs skip themselves when `artifacts/` is absent, so the stub keeps
//! `cargo test` green while failing loudly (never silently) if graph
//! execution is actually attempted.

#![allow(dead_code)]

const STUB_MSG: &str =
    "xla stub: no PJRT runtime in this build (vendored offline substitute); \
     run with real xla bindings to execute compiled graphs";

/// Error type; the callers only format it with `{:?}`.
pub struct XlaError(pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err<T>() -> Result<T> {
    Err(XlaError(STUB_MSG.to_string()))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Host-side tensor of f32s (or a tuple of them). Fully functional.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64], tuple: None }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        if expect < 0 || expect as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), tuple: None })
    }

    /// Flat host copy of the elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(XlaError("to_vec on a tuple literal".to_string()));
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| XlaError("to_tuple on a non-tuple literal".to_string()))
    }

    /// Decompose a 1-tuple literal into its single part.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut parts = self.to_tuple()?;
        if parts.len() != 1 {
            return Err(XlaError(format!("to_tuple1 on a {}-tuple", parts.len())));
        }
        Ok(parts.remove(0))
    }

    /// Declared dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (stub: never constructible without a runtime).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Compiled executable (stub: never constructible without a runtime).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }

    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// Stub: creating a CPU client fails cleanly (no PJRT available).
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
