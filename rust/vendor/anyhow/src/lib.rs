//! Minimal offline substitute for the `anyhow` crate.
//!
//! The container has no crates.io access, so this vendored shim provides
//! exactly the surface the workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//! Semantics match `anyhow` where it matters here:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`;
//! * `.context(..)` / `.with_context(..)` wrap an error with an outer
//!   message; `Display` prints the full `outer: inner: ...` chain (a
//!   superset of anyhow's default single-message `Display`, which keeps
//!   substring assertions in tests working).

use std::fmt;

/// An error: a message plus an optional wrapped cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), cause: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// The outermost message (no cause chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        msgs.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message, then the cause chain.
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion does not overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(match err {
                None => Error { msg, cause: None },
                Some(inner) => Error { msg, cause: Some(Box::new(inner)) },
            });
        }
        err.expect("chain is non-empty")
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing file"));
        r?;
        Ok(())
    }

    #[test]
    fn io_error_converts_and_displays() {
        let err = fails_io().unwrap_err();
        assert!(err.to_string().contains("missing file"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let err = fails_io().with_context(|| "reading manifest").unwrap_err();
        let s = err.to_string();
        assert!(s.starts_with("reading manifest"), "{s}");
        assert!(s.contains("missing file"), "{s}");
        assert_eq!(err.chain().count(), 2);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        fn g(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(7)
        }
        assert_eq!(g(true).unwrap(), 7);
        assert!(g(false).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("empty").is_err());
        assert_eq!(Some(3u32).context("empty").unwrap(), 3);
    }
}
