//! Failure-injection integration tests: the framework must fail loudly
//! and cleanly — not hang or corrupt — when artifacts are missing,
//! malformed, or inconsistent with the request — and the same for the
//! remote replay transport when the server is unreachable or dies
//! mid-RPC.

use pal_rl::coordinator::{train, TrainConfig};
use pal_rl::remote::{BackoffPolicy, ConnectionPolicy, RemoteClient, Request};
use pal_rl::runtime::{Manifest, Runtime};
use std::os::unix::net::UnixListener;
use std::time::{Duration, Instant};

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_dir()).join("manifest.json").exists()
}

#[test]
fn missing_artifact_dir_is_clean_error() {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.artifact_dir = "/nonexistent/pal/artifacts".into();
    let err = train(&cfg).unwrap_err().to_string();
    assert!(err.contains("manifest") || err.contains("artifacts"), "{err}");
}

#[test]
fn unknown_algo_env_pair_is_clean_error() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = TrainConfig::new("dqn", "Pendulum-v1"); // not generated
    cfg.artifact_dir = artifacts_dir().into();
    let err = train(&cfg).unwrap_err().to_string();
    assert!(err.contains("dqn_Pendulum-v1"), "{err}");
}

#[test]
fn unknown_environment_is_clean_error() {
    if !have_artifacts() {
        return;
    }
    // Manifest entry exists but the rust env registry must still agree;
    // fabricate a config whose env cannot be instantiated.
    let mut cfg = TrainConfig::new("dqn", "NoSuchEnv-v0");
    cfg.artifact_dir = artifacts_dir().into();
    assert!(train(&cfg).is_err());
}

#[test]
fn malformed_manifest_rejected() {
    let dir = std::env::temp_dir().join("pal_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // Unparseable JSON.
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // Parseable but inconsistent param table (offsets don't tile).
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"artifacts":[{"id":"x_y","algo":"dqn","env":"y",
            "obs_dim":2,"flat_act_dim":1,"n_actions":2,"act_dim":null,
            "act_high":1.0,"discrete":true,"hidden":[8],"batch_size":4,
            "gamma":0.99,"params_file":"x.bin","total_param_size":10,
            "params":[{"name":"w","shape":[2,2],"offset":5,"size":4}],
            "graphs":{}}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("inconsistent"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_input_length_rejected_not_crash() {
    if !have_artifacts() {
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let info = manifest.get("dqn_CartPole-v1").unwrap();
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_model(info).unwrap();
    let graph = model.graph("act").unwrap();
    // Too few inputs.
    let err = graph.run(&[&[0.0f32; 4][..]]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
    // Right arity, wrong element count on one input.
    let params = info.load_initial_params().unwrap();
    let mut inputs: Vec<&[f32]> = model.param_slices(&params).unwrap();
    let bad_obs = [0.0f32; 3]; // obs_dim is 4
    inputs.push(&bad_obs);
    let err = graph.run(&inputs).unwrap_err().to_string();
    assert!(err.contains("obs"), "{err}");
}

#[test]
fn corrupt_params_blob_rejected() {
    if !have_artifacts() {
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let mut info = manifest.get("dqn_CartPole-v1").unwrap().clone();
    let dir = std::env::temp_dir().join("pal_bad_params");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("short.bin");
    std::fs::write(&bad, [0u8; 12]).unwrap();
    info.params_file = bad;
    let err = info.load_initial_params().unwrap_err().to_string();
    assert!(err.contains("bytes"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A short-fuse policy so the remote failure tests finish in well under
/// a second instead of the 30 s production reconnect deadline.
fn short_policy() -> ConnectionPolicy {
    ConnectionPolicy {
        rpc_timeout: Duration::from_millis(500),
        backoff: BackoffPolicy::default().with_deadline(Duration::from_millis(200)),
    }
}

#[test]
fn remote_server_unreachable_is_clean_error() {
    // A plain connect does not retry: an absent server is an immediate,
    // descriptive error naming the socket, never a hang.
    let start = Instant::now();
    let err = RemoteClient::connect("/nonexistent/pal/replay.sock").unwrap_err().to_string();
    assert!(err.contains("connecting to replay server"), "{err}");
    assert!(err.contains("/nonexistent/pal/replay.sock"), "{err}");
    assert!(start.elapsed() < Duration::from_secs(5), "unreachable server must fail fast");
}

#[test]
fn remote_mid_rpc_disconnect_is_descriptive_not_hang() {
    let dir = std::env::temp_dir().join(format!("pal_midrpc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("replay.sock");
    let listener = UnixListener::bind(&sock).unwrap();
    // Accept the dial, then slam the connection shut without answering
    // a single frame — the worst-case mid-RPC peer death.
    let acceptor = std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            drop(stream);
        }
    });
    let mut client = RemoteClient::connect_with(&sock, short_policy()).unwrap();
    acceptor.join().unwrap();

    let start = Instant::now();
    let err = client.stats().unwrap_err().to_string();
    assert!(err.contains("replay transport"), "{err}");
    assert!(start.elapsed() < Duration::from_secs(5), "mid-RPC disconnect must not hang: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn remote_reconnect_gives_up_at_the_deadline_with_a_descriptive_error() {
    let dir = std::env::temp_dir().join(format!("pal_giveup_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("replay.sock");
    let listener = UnixListener::bind(&sock).unwrap();
    let acceptor = std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            drop(stream);
        }
    });
    let mut client = RemoteClient::connect_with(&sock, short_policy()).unwrap();
    acceptor.join().unwrap();
    // Remove the socket so every redial fails: the resilient path must
    // give up at the (short) deadline with a descriptive error, not
    // spin forever.
    std::fs::remove_file(&sock).unwrap();

    let start = Instant::now();
    let err = client.call_resilient(&Request::Stats).unwrap_err().to_string();
    assert!(err.contains("gave up"), "{err}");
    assert!(start.elapsed() < Duration::from_secs(10), "reconnect must respect the deadline");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hlo_text_garbage_rejected() {
    let dir = std::env::temp_dir().join("pal_bad_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("garbage.hlo.txt");
    std::fs::write(&path, "HloModule definitely { not valid").unwrap();
    let rt = Runtime::cpu().unwrap();
    assert!(rt.load_hlo_text(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
