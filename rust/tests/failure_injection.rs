//! Failure-injection integration tests: the framework must fail loudly
//! and cleanly — not hang or corrupt — when artifacts are missing,
//! malformed, or inconsistent with the request.

use pal_rl::coordinator::{train, TrainConfig};
use pal_rl::runtime::{Manifest, Runtime};

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(artifacts_dir()).join("manifest.json").exists()
}

#[test]
fn missing_artifact_dir_is_clean_error() {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.artifact_dir = "/nonexistent/pal/artifacts".into();
    let err = train(&cfg).unwrap_err().to_string();
    assert!(err.contains("manifest") || err.contains("artifacts"), "{err}");
}

#[test]
fn unknown_algo_env_pair_is_clean_error() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = TrainConfig::new("dqn", "Pendulum-v1"); // not generated
    cfg.artifact_dir = artifacts_dir().into();
    let err = train(&cfg).unwrap_err().to_string();
    assert!(err.contains("dqn_Pendulum-v1"), "{err}");
}

#[test]
fn unknown_environment_is_clean_error() {
    if !have_artifacts() {
        return;
    }
    // Manifest entry exists but the rust env registry must still agree;
    // fabricate a config whose env cannot be instantiated.
    let mut cfg = TrainConfig::new("dqn", "NoSuchEnv-v0");
    cfg.artifact_dir = artifacts_dir().into();
    assert!(train(&cfg).is_err());
}

#[test]
fn malformed_manifest_rejected() {
    let dir = std::env::temp_dir().join("pal_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    // Unparseable JSON.
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // Parseable but inconsistent param table (offsets don't tile).
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version":1,"artifacts":[{"id":"x_y","algo":"dqn","env":"y",
            "obs_dim":2,"flat_act_dim":1,"n_actions":2,"act_dim":null,
            "act_high":1.0,"discrete":true,"hidden":[8],"batch_size":4,
            "gamma":0.99,"params_file":"x.bin","total_param_size":10,
            "params":[{"name":"w","shape":[2,2],"offset":5,"size":4}],
            "graphs":{}}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("inconsistent"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_input_length_rejected_not_crash() {
    if !have_artifacts() {
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let info = manifest.get("dqn_CartPole-v1").unwrap();
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_model(info).unwrap();
    let graph = model.graph("act").unwrap();
    // Too few inputs.
    let err = graph.run(&[&[0.0f32; 4][..]]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
    // Right arity, wrong element count on one input.
    let params = info.load_initial_params().unwrap();
    let mut inputs: Vec<&[f32]> = model.param_slices(&params).unwrap();
    let bad_obs = [0.0f32; 3]; // obs_dim is 4
    inputs.push(&bad_obs);
    let err = graph.run(&inputs).unwrap_err().to_string();
    assert!(err.contains("obs"), "{err}");
}

#[test]
fn corrupt_params_blob_rejected() {
    if !have_artifacts() {
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let mut info = manifest.get("dqn_CartPole-v1").unwrap().clone();
    let dir = std::env::temp_dir().join("pal_bad_params");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("short.bin");
    std::fs::write(&bad, [0u8; 12]).unwrap();
    info.params_file = bad;
    let err = info.load_initial_params().unwrap_err().to_string();
    assert!(err.contains("bytes"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hlo_text_garbage_rejected() {
    let dir = std::env::temp_dir().join("pal_bad_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("garbage.hlo.txt");
    std::fs::write(&path, "HloModule definitely { not valid").unwrap();
    let rt = Runtime::cpu().unwrap();
    assert!(rt.load_hlo_text(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
