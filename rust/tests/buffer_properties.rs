//! Property-based tests on the replay-buffer invariants (the paper's
//! correctness claims, §IV), driven by the in-repo `util::prop` harness
//! across randomized shapes, fan-outs, priorities and op interleavings.

use pal_rl::replay::{
    GlobalLockReplay, KArySumTree, PrioritizedConfig, PrioritizedReplay, ReplayBuffer,
    SampleBatch, Transition,
};
use pal_rl::util::prop::{check, Gen, Pair, UsizeIn, VecF32};
use pal_rl::util::rng::Rng;

fn tr(v: f32, obs_dim: usize, act_dim: usize) -> Transition {
    Transition {
        obs: vec![v; obs_dim],
        action: vec![v; act_dim],
        next_obs: vec![v + 1.0; obs_dim],
        reward: v,
        done: false,
    }
}

/// Invariant: root == Σ leaves for any (capacity, fanout) and any
/// sequence of updates.
#[test]
fn prop_tree_root_equals_leaf_sum() {
    let gen = Pair(
        Pair(UsizeIn { lo: 1, hi: 300 }, UsizeIn { lo: 2, hi: 128 }),
        VecF32 { min_len: 1, max_len: 200, lo: 0.0, hi: 10.0 },
    );
    check("root=Σleaves", 42, 60, &gen, |((cap, fanout), prios)| {
        let t = KArySumTree::new(*cap, *fanout);
        let mut expect = 0.0f64;
        let mut rng = Rng::new(7);
        let mut vals = vec![0.0f32; *cap];
        for &p in prios {
            let i = rng.below_usize(*cap);
            vals[i] = p;
            t.update(i, p);
        }
        for &v in &vals {
            expect += v as f64;
        }
        let got = t.total() as f64;
        let scale = expect.abs().max(1.0);
        if (got - expect).abs() / scale < 1e-3 {
            Ok(())
        } else {
            Err(format!("total {got} vs Σ {expect} (cap {cap}, K {fanout})"))
        }
    });
}

/// Invariant: prefix-sum descent never returns a zero-priority leaf when
/// the tree holds positive mass, for any sparsity pattern.
#[test]
fn prop_descent_skips_zero_leaves() {
    let gen = Pair(UsizeIn { lo: 2, hi: 128 }, UsizeIn { lo: 4, hi: 256 });
    check("no-zero-leaf", 43, 80, &gen, |(fanout, cap)| {
        let t = KArySumTree::new(*cap, *fanout);
        let mut rng = Rng::new(*cap as u64 ^ (*fanout as u64) << 8);
        let mut any = false;
        for i in 0..*cap {
            if rng.chance(0.3) {
                t.update(i, rng.f32() + 0.01);
                any = true;
            }
        }
        if !any {
            t.update(0, 1.0);
        }
        for k in 0..200 {
            let x = (k as f32 / 200.0) * t.total();
            let (idx, p) = t.prefix_sum_index(x);
            if p <= 0.0 {
                return Err(format!("zero leaf {idx} at x={x} (cap {cap}, K {fanout})"));
            }
        }
        Ok(())
    });
}

/// Invariant: after any insert/sample/update interleaving the buffer's
/// tree satisfies root≈Σleaves and len never exceeds capacity.
#[test]
fn prop_buffer_interleaving_consistent() {
    let gen = Pair(
        Pair(UsizeIn { lo: 8, hi: 256 }, UsizeIn { lo: 16, hi: 64 }),
        UsizeIn { lo: 1, hi: 2000 },
    );
    check("interleave", 44, 25, &gen, |((cap, fanout), ops)| {
        let b = PrioritizedReplay::new(PrioritizedConfig {
            capacity: *cap,
            obs_dim: 3,
            act_dim: 1,
            fanout: *fanout,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        });
        let mut rng = Rng::new(*ops as u64);
        let mut out = SampleBatch::default();
        for i in 0..*ops {
            match rng.below(10) {
                0..=5 => b.insert(&tr(i as f32, 3, 1)),
                6..=7 => {
                    b.sample(8, &mut rng, &mut out);
                }
                _ => {
                    if !out.indices.is_empty() {
                        let tds: Vec<f32> =
                            out.indices.iter().map(|_| rng.f32() * 3.0).collect();
                        b.update_priorities(&out.indices.clone(), &tds);
                    }
                }
            }
            if b.len() > *cap {
                return Err(format!("len {} > capacity {cap}", b.len()));
            }
        }
        b.rebuild_tree();
        let err = b.tree().invariant_error();
        if err < 1e-4 {
            Ok(())
        } else {
            Err(format!("invariant error {err} after {ops} ops"))
        }
    });
}

/// Invariant: sampled importance weights are in (0, 1] and sampled
/// indices are always < len, for both prioritized implementations.
#[test]
fn prop_sample_outputs_well_formed() {
    let gen = Pair(UsizeIn { lo: 1, hi: 200 }, UsizeIn { lo: 1, hi: 64 });
    check("sample-well-formed", 45, 50, &gen, |(inserts, batch)| {
        let impls: Vec<Box<dyn ReplayBuffer>> = vec![
            Box::new(PrioritizedReplay::new(PrioritizedConfig {
                capacity: 128,
                obs_dim: 2,
                act_dim: 1,
                fanout: 16,
                alpha: 0.7,
                beta: 0.5,
                lazy_writing: true,
                shards: 1,
            })),
            Box::new(GlobalLockReplay::new(128, 2, 1, 0.7, 0.5)),
        ];
        for b in &impls {
            let mut rng = Rng::new(9);
            for i in 0..*inserts {
                b.insert(&tr(i as f32, 2, 1));
            }
            let mut out = SampleBatch::default();
            if b.sample(*batch, &mut rng, &mut out) {
                let n = b.len();
                for (&idx, &w) in out.indices.iter().zip(&out.is_weights) {
                    if idx >= n.max(128.min(*inserts)) && idx >= 128 {
                        return Err(format!("{}: index {idx} out of range", b.name()));
                    }
                    if !(w > 0.0 && w <= 1.0 + 1e-5) {
                        return Err(format!("{}: weight {w} out of (0,1]", b.name()));
                    }
                }
                if out.obs.len() != out.len() * 2 {
                    return Err(format!("{}: obs length mismatch", b.name()));
                }
            } else if *inserts > 0 {
                return Err(format!("{}: sample failed with {inserts} rows", b.name()));
            }
        }
        Ok(())
    });
}

/// Invariant: priorities round-trip through update/get as (|td|+ε)^α.
#[test]
fn prop_priority_roundtrip() {
    let gen = VecF32 { min_len: 1, max_len: 64, lo: 0.0, hi: 50.0 };
    check("priority-roundtrip", 46, 60, &gen, |tds| {
        let b = PrioritizedReplay::new(PrioritizedConfig {
            capacity: 64,
            obs_dim: 2,
            act_dim: 1,
            fanout: 16,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        });
        for i in 0..tds.len() {
            b.insert(&tr(i as f32, 2, 1));
        }
        let idx: Vec<usize> = (0..tds.len()).collect();
        b.update_priorities(&idx, tds);
        for (i, &td) in tds.iter().enumerate() {
            let want = b.transform_priority(td);
            let got = b.get_priority(i);
            if (got - want).abs() > 1e-5 * want.max(1.0) {
                return Err(format!("slot {i}: got {got}, want {want}"));
            }
        }
        Ok(())
    });
}
