//! Multi-threaded stress test of the sharded prioritized replay buffer:
//! N actor threads inserting with affinity routing, M learner threads
//! sampling and feeding priorities back through the batched update path,
//! all against one shared buffer. Asserts the paper-level invariants
//! survive the full concurrent protocol:
//!
//! * bounded per-shard tree `invariant_error` after quiescence;
//! * no zero-priority transition is ever sampled;
//! * per-shard `LockStats` sum exactly to the merged snapshot, and the
//!   op counters account for every operation issued.

use pal_rl::replay::{
    LockStatsSnapshot, PrioritizedConfig, ReplayBuffer, SampleBatch,
    ShardedPrioritizedReplay, Transition,
};
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const ACTORS: usize = 4;
const LEARNERS: usize = 3;
const SHARDS: usize = 4;
const CAPACITY: usize = 4_096;
const INSERTS_PER_ACTOR: usize = 3_000;
const ROUNDS_PER_LEARNER: usize = 400;
const BATCH: usize = 32;

fn tr(v: f32) -> Transition {
    Transition {
        obs: vec![v; 4],
        action: vec![v; 2],
        next_obs: vec![v + 1.0; 4],
        reward: v,
        done: false,
    }
}

fn mk() -> ShardedPrioritizedReplay {
    ShardedPrioritizedReplay::new(PrioritizedConfig {
        capacity: CAPACITY,
        obs_dim: 4,
        act_dim: 2,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: SHARDS,
    })
}

#[test]
fn actors_and_learners_stress_sharded_buffer() {
    let b = Arc::new(mk());
    // Warm every shard so learners can sample immediately.
    for a in 0..ACTORS {
        for i in 0..256 {
            b.insert_from(a, &tr(i as f32));
        }
    }
    let updated_pairs = Arc::new(AtomicU64::new(0));
    let sampled_batches = Arc::new(AtomicU64::new(0));

    std::thread::scope(|s| {
        for a in 0..ACTORS {
            let b = Arc::clone(&b);
            s.spawn(move || {
                for i in 0..INSERTS_PER_ACTOR {
                    b.insert_from(a, &tr((a * 100_000 + i) as f32));
                }
            });
        }
        for l in 0..LEARNERS {
            let b = Arc::clone(&b);
            let updated_pairs = Arc::clone(&updated_pairs);
            let sampled_batches = Arc::clone(&sampled_batches);
            s.spawn(move || {
                let mut rng = Rng::new(77 + l as u64);
                let mut out = SampleBatch::default();
                for _ in 0..ROUNDS_PER_LEARNER {
                    if b.sample(BATCH, &mut rng, &mut out) {
                        sampled_batches.fetch_add(1, Ordering::Relaxed);
                        // Full batches only, and never a zero-priority row.
                        assert_eq!(out.len(), BATCH);
                        assert!(
                            out.priorities.iter().all(|&p| p > 0.0),
                            "sampled a zero-priority transition"
                        );
                        for &idx in &out.indices {
                            assert!(idx < b.capacity());
                        }
                        let idx = out.indices.clone();
                        let tds: Vec<f32> =
                            idx.iter().map(|_| rng.f32() * 5.0).collect();
                        b.update_priorities(&idx, &tds);
                        updated_pairs.fetch_add(idx.len() as u64, Ordering::Relaxed);
                    }
                }
            });
        }
    });

    // --- Quiescent invariants ---------------------------------------
    // Every actor inserted more than a shard's capacity: all shards full.
    assert_eq!(b.len(), b.capacity());
    // Tree invariant per shard, bounded after the fp drift is squashed.
    for s in 0..b.shard_count() {
        // Concurrent propagation leaves only fp drift, which the rebuild
        // removes; both bounds must hold.
        assert!(
            b.shard(s).tree().invariant_error() < 1e-2,
            "shard {s} diverged during the run"
        );
    }
    b.rebuild_trees();
    assert!(b.invariant_error() < 1e-5, "invariant after rebuild");

    // --- Stats consistency ------------------------------------------
    let merged = b.merged_stats();
    let mut manual = LockStatsSnapshot::default();
    for s in 0..b.shard_count() {
        manual.accumulate(&b.shard(s).stats.snapshot());
    }
    assert_eq!(merged.inserts, manual.inserts);
    assert_eq!(merged.updates, manual.updates);
    assert_eq!(merged.global_acquisitions, manual.global_acquisitions);
    assert_eq!(merged.leaf_acquisitions, manual.leaf_acquisitions);
    // Sample ops are counted at the wrapper (one per sample() call, like
    // the single-tree buffer), NOT per shard descent.
    assert_eq!(merged.samples, (LEARNERS * ROUNDS_PER_LEARNER) as u64);
    assert_eq!(manual.samples, 0);
    // Every issued op is accounted for in the merged counters.
    let total_inserts = (ACTORS * (256 + INSERTS_PER_ACTOR)) as u64;
    assert_eq!(merged.inserts, total_inserts);
    assert_eq!(merged.updates, updated_pairs.load(Ordering::Relaxed));
    assert!(sampled_batches.load(Ordering::Relaxed) > 0, "no learner ever sampled");
    // Batched updates amortize locking: with BATCH=32 pairs spread over
    // at most SHARDS shards per round, global acquisitions from updates
    // are far below one per pair. Inserts take exactly 2 acquisitions
    // each (lazy writing); each sample op takes at most one descent per
    // shard plus one retry descent.
    let insert_acqs = 2 * total_inserts;
    let max_update_acqs =
        (SHARDS as u64) * (LEARNERS as u64) * (ROUNDS_PER_LEARNER as u64);
    let max_sample_acqs = merged.samples * (SHARDS as u64 + 1);
    assert!(
        merged.global_acquisitions <= insert_acqs + max_update_acqs + max_sample_acqs,
        "lock amortization violated: {} acquisitions",
        merged.global_acquisitions
    );

    // Actor affinity: with 4 actors on 4 shards, every shard's inserts
    // come from exactly one actor.
    for s in 0..b.shard_count() {
        assert_eq!(
            b.shard(s).stats.snapshot().inserts,
            (256 + INSERTS_PER_ACTOR) as u64,
            "shard {s} insert routing"
        );
    }
}

#[test]
fn stress_survives_eviction_pressure_with_tiny_shards() {
    // Tiny per-shard capacity maximizes FIFO eviction races between the
    // lazy-writing zero window and concurrent sampling.
    let b = Arc::new(ShardedPrioritizedReplay::new(PrioritizedConfig {
        capacity: 256, // 64 per shard
        obs_dim: 4,
        act_dim: 2,
        fanout: 16,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 4,
    }));
    for a in 0..4 {
        for i in 0..64 {
            b.insert_from(a, &tr(i as f32));
        }
    }
    std::thread::scope(|s| {
        for a in 0..2 {
            let b = Arc::clone(&b);
            s.spawn(move || {
                for i in 0..20_000 {
                    b.insert_from(a, &tr(i as f32));
                }
            });
        }
        for l in 0..2 {
            let b = Arc::clone(&b);
            s.spawn(move || {
                let mut rng = Rng::new(5 + l as u64);
                let mut out = SampleBatch::default();
                for _ in 0..2_000 {
                    if b.sample(16, &mut rng, &mut out) {
                        assert!(out.priorities.iter().all(|&p| p > 0.0));
                        let idx = out.indices.clone();
                        b.update_priorities(&idx, &vec![0.7; idx.len()]);
                    }
                }
            });
        }
    });
    assert_eq!(b.len(), 256);
    b.rebuild_trees();
    assert!(b.invariant_error() < 1e-5);
}
