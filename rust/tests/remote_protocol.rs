//! Protocol fuzz/property tests for the remote replay front-end: every
//! malformed input — truncated, bit-flipped, oversized-length,
//! wrong-magic frames, garbage payloads — must yield a descriptive
//! error, never a panic, and must never leave a half-applied insert in
//! the served tables.

mod common;

use common::{start_server, stop_server};
use pal_rl::remote::{read_frame, write_frame, RemoteClient, Request, Response, FRAME_MAGIC};
use pal_rl::replay::UniformReplay;
use pal_rl::service::{ItemKind, RateLimiter, ReplayService, Table, WriterStep};
use pal_rl::util::blob::crc32;
use pal_rl::util::prop::{check, Pair, UsizeIn};
use pal_rl::util::rng::Rng;
use std::io::{Cursor, Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::Arc;

fn step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32, -(i as f32)],
        action: vec![0.5],
        next_obs: vec![i as f32 + 1.0, -(i as f32)],
        reward: 1.0,
        done: false,
        truncated: false,
    }
}

fn tiny_service() -> Arc<ReplayService> {
    Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::new(UniformReplay::new(64, 2, 1)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap(),
    )
}

/// A frame with a representative request inside, as raw bytes.
fn sample_frame() -> Vec<u8> {
    let req = Request::Append {
        actor_id: 3,
        seq: 0,
        dropped: 0,
        steps: vec![step(0), step(1), step(2)],
    };
    let mut buf = Vec::new();
    write_frame(&mut buf, &req.encode()).unwrap();
    buf
}

#[test]
fn prop_truncated_frames_error_at_every_cut() {
    let frame = sample_frame();
    let gen = UsizeIn { lo: 1, hi: frame.len() - 1 };
    check("frame-truncation", 0x7A11, 300, &gen, |&cut| {
        let mut cur = Cursor::new(frame[..cut].to_vec());
        match read_frame(&mut cur) {
            Err(e) => {
                let msg = e.to_string();
                if msg.is_empty() {
                    Err("error with empty message".into())
                } else {
                    Ok(())
                }
            }
            Ok(got) => Err(format!("cut at {cut} decoded to {got:?}")),
        }
    });
}

#[test]
fn prop_bit_flips_anywhere_are_rejected() {
    let frame = sample_frame();
    let gen = Pair(UsizeIn { lo: 0, hi: frame.len() - 1 }, UsizeIn { lo: 0, hi: 7 });
    check("frame-bitflip", 0xF11B, 400, &gen, |&(pos, bit)| {
        let mut bytes = frame.clone();
        bytes[pos] ^= 1 << bit;
        // A flip in the length field may make the frame "longer" than
        // the buffer (truncation error) or shorter (checksum error);
        // flips in magic/payload/crc hit their own checks. All must
        // fail — the decoder may never hand back a frame.
        match read_frame(&mut Cursor::new(bytes)) {
            Err(_) => Ok(()),
            Ok(got) => Err(format!("flip at byte {pos} bit {bit} decoded to {got:?}")),
        }
    });
}

#[test]
fn oversized_length_and_wrong_magic_are_descriptive() {
    // Oversized length field: rejected before any allocation.
    let mut oversized = Vec::new();
    oversized.extend_from_slice(FRAME_MAGIC);
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    oversized.extend_from_slice(&[0u8; 64]);
    let err = read_frame(&mut Cursor::new(oversized)).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "{err}");

    // Wrong magic (e.g. a future protocol version).
    let mut wrong = sample_frame();
    wrong[7] = b'9';
    let err = read_frame(&mut Cursor::new(wrong)).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");
}

#[test]
fn prop_request_decoder_never_panics_and_roundtrips_valid_decodes() {
    // Random payloads: decode must never panic; when garbage happens to
    // decode as a valid request, re-encoding it must roundtrip (the
    // encoding is canonical).
    let gen = Pair(UsizeIn { lo: 0, hi: 200 }, UsizeIn { lo: 0, hi: u32::MAX as usize });
    check("request-fuzz", 0xDECD, 500, &gen, |&(len, seed)| {
        let mut rng = Rng::new(seed as u64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        if let Ok(req) = Request::decode(&bytes) {
            let redecoded = Request::decode(&req.encode())
                .map_err(|e| format!("canonical re-decode failed: {e}"))?;
            if redecoded != req {
                return Err(format!("roundtrip changed the request: {req:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn corrupted_append_is_rejected_with_no_half_applied_insert() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));

    // A valid append lands fully.
    let mut client = RemoteClient::connect(&path).unwrap();
    let (consumed, _) = client.append(0, &[step(0), step(1)]).unwrap();
    assert_eq!(consumed, 2);
    assert_eq!(service.table("replay").unwrap().len(), 2);
    let inserts_before = service.table("replay").unwrap().stats_snapshot().inserts;
    assert_eq!(inserts_before, 2);

    // The same append with one payload byte flipped: the frame checksum
    // fails, the server answers a descriptive error and applies nothing.
    let req = Request::Append {
        actor_id: 0,
        seq: 0,
        dropped: 0,
        steps: vec![step(2), step(3), step(4)],
    };
    let mut frame = Vec::new();
    write_frame(&mut frame, &req.encode()).unwrap();
    let payload_start = FRAME_MAGIC.len() + 4;
    frame[payload_start + 9] ^= 0xFF;
    let mut raw = UnixStream::connect(&path).unwrap();
    raw.write_all(&frame).unwrap();
    let resp = read_frame(&mut raw).expect("server must answer").expect("with a frame");
    match Response::decode(&resp).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("checksum"), "{message}");
        }
        other => panic!("corrupt frame got {other:?}"),
    }
    // The connection was dropped after the protocol error.
    let mut probe = [0u8; 1];
    assert_eq!(raw.read(&mut probe).unwrap_or(0), 0, "connection must be closed");

    // No step of the corrupted batch was applied — not even the ones
    // "before" the flipped byte.
    assert_eq!(service.table("replay").unwrap().len(), 2);
    assert_eq!(
        service.table("replay").unwrap().stats_snapshot().inserts,
        inserts_before,
        "a corrupted frame must never half-apply an insert"
    );

    // The server still serves fresh connections afterwards.
    let mut after = RemoteClient::connect(&path).unwrap();
    let stats = after.stats().unwrap();
    assert_eq!(stats[0].stats.inserts, 2);

    // Quiesce before shutdown so the server's drain returns promptly.
    drop(client);
    drop(after);
    stop_server(&path, handle);
}

#[test]
fn server_survives_garbage_streams_and_bad_payloads() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));

    // Random garbage streams: the server may answer an error frame or
    // just drop the connection; it must keep serving either way.
    let mut rng = Rng::new(0xBAD5EED);
    for round in 0..20 {
        let mut s = UnixStream::connect(&path).unwrap();
        let len = 1 + rng.below_usize(300);
        let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // The write itself may fail once the server closes its end —
        // that is fine; panics and hangs are not.
        let _ = s.write_all(&garbage);
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
        drop(s);
        // Still alive?
        let mut probe = RemoteClient::connect(&path)
            .unwrap_or_else(|e| panic!("server died after garbage round {round}: {e}"));
        probe.stats().expect("stats after garbage");
    }

    // A checksummed frame with a bogus payload keeps the connection up.
    let mut client = RemoteClient::connect(&path).unwrap();
    let bogus = Request::Sample { table: "no-such-table".into(), batch: 4, seq: 0 };
    match client.call(&bogus).unwrap() {
        Response::Error { message } => assert!(message.contains("unknown table"), "{message}"),
        other => panic!("unknown table got {other:?}"),
    }
    // Same connection still works.
    client.stats().expect("stats after app-level error");

    // Tables were never touched by any of it.
    assert_eq!(service.table("replay").unwrap().len(), 0);

    drop(client);
    stop_server(&path, handle);
}

#[test]
fn invalid_priority_values_rejected_at_decode() {
    // Regression: NaN/negative/±inf |TD| values used to decode cleanly
    // and flow into `set_leaf`, where a NaN permanently poisons every
    // interior sum up to the root.
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.0] {
        let req = Request::UpdatePriorities {
            table: "replay".into(),
            indices: vec![0],
            td_abs: vec![bad],
            seq: 0,
        };
        match Request::decode(&req.encode()) {
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("invalid |TD|"), "{msg}");
            }
            Ok(got) => panic!("invalid |TD| {bad} decoded to {got:?}"),
        }
    }
    // Valid values still decode and roundtrip.
    let ok = Request::UpdatePriorities {
        table: "replay".into(),
        indices: vec![0, 1],
        td_abs: vec![0.0, 2.5],
        seq: 1,
    };
    assert_eq!(Request::decode(&ok.encode()).unwrap(), ok);
}

#[test]
fn nan_priority_update_answered_with_error_frame() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let mut s = UnixStream::connect(&path).unwrap();
    // The encoder does not validate (the decoder is the gate), so a
    // hostile/buggy client CAN put a NaN on the wire.
    let req = Request::UpdatePriorities {
        table: "replay".into(),
        indices: vec![0],
        td_abs: vec![f32::NAN],
        seq: 0,
    };
    let mut buf = Vec::new();
    write_frame(&mut buf, &req.encode()).unwrap();
    s.write_all(&buf).unwrap();
    let frame = read_frame(&mut s).unwrap().expect("error frame expected");
    match Response::decode(&frame).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("bad request"), "{message}");
            assert!(message.contains("invalid |TD|"), "{message}");
        }
        other => panic!("NaN priority update got {other:?}"),
    }
    // The frame was well-formed, so the connection survives...
    let probe = Request::Stats;
    let mut buf = Vec::new();
    write_frame(&mut buf, &probe.encode()).unwrap();
    s.write_all(&buf).unwrap();
    let frame = read_frame(&mut s).unwrap().expect("stats after rejected update");
    assert!(matches!(Response::decode(&frame).unwrap(), Response::Stats { .. }));
    // ...and the table was never touched.
    assert_eq!(service.table("replay").unwrap().stats_snapshot().priority_updates, 0);
    drop(s);
    stop_server(&path, handle);
}

#[test]
fn replayed_append_seq_is_deduped_over_the_wire() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));

    let mut client = RemoteClient::connect(&path).unwrap();
    client.hello(7).unwrap();

    // The same sequenced append sent twice (a reconnect replay): both
    // get the ack, the table sees the steps exactly once.
    let req = Request::Append { actor_id: 0, seq: 1, dropped: 0, steps: vec![step(0), step(1)] };
    for round in 0..2 {
        match client.call(&req).unwrap() {
            Response::Appended { consumed, .. } => assert_eq!(consumed, 2, "round {round}"),
            other => panic!("round {round} got {other:?}"),
        }
    }
    assert_eq!(service.table("replay").unwrap().len(), 2, "replayed seq must not double-insert");
    assert_eq!(service.table("replay").unwrap().stats_snapshot().inserts, 2);

    // A gap past the expected seq is a descriptive error, not a panic,
    // and applies nothing.
    let gap = Request::Append { actor_id: 0, seq: 9, dropped: 0, steps: vec![step(2)] };
    match client.call(&gap).unwrap() {
        Response::Error { message } => assert!(message.contains("seq gap"), "{message}"),
        other => panic!("seq gap got {other:?}"),
    }
    assert_eq!(service.table("replay").unwrap().len(), 2);

    drop(client);
    stop_server(&path, handle);
}

#[test]
fn stale_session_id_gets_a_fresh_session_not_a_panic() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));

    // Quoting a session id the server never issued (e.g. from before a
    // restart) must bind a fresh session, flagged un-resumed so the
    // client knows to re-ship everything.
    let mut client = RemoteClient::connect(&path).unwrap();
    match client
        .call(&Request::Hello { rng_seed: 3, session: 0xDEAD_BEEF, tables: vec![] })
        .unwrap()
    {
        Response::Hello { session, resumed, next_seq, .. } => {
            assert!(!resumed, "unknown session id must not claim resumption");
            assert_ne!(session, 0xDEAD_BEEF, "server must mint its own id");
            assert_ne!(session, 0, "fresh session must be registered");
            assert_eq!(next_seq, 1, "fresh session starts the sequence over");
        }
        other => panic!("stale hello got {other:?}"),
    }
    // The connection stays fully usable on the fresh session.
    client.stats().expect("stats after stale hello");

    drop(client);
    stop_server(&path, handle);
}

// ---------------------------------------------------------------------------
// Chunked state streaming over the live wire. The server-side staging
// state machine is unit-tested next to its implementation; these tests
// prove the connection loop end of it: every malformed upload —
// truncated mid-chunk, out-of-order sequence, flipped payload bytes,
// oversized chunk, hostile header — is a descriptive error over the
// socket and never leaves a half-restored table.
// ---------------------------------------------------------------------------

/// Encoded `ServiceState` of a tiny service holding `n` steps — the
/// payload the chunked-upload tests push over the wire.
fn donor_state(n: usize) -> Vec<u8> {
    let donor = tiny_service();
    let mut w = donor.writer(0);
    for i in 0..n {
        w.append(step(i));
    }
    donor.checkpoint().expect("donor checkpoint").encode()
}

/// The well-formed chunk-upload request sequence for `state`:
/// `ChunkBegin`, one `Chunk` per `chunk_len`-byte piece, `ChunkEnd`.
fn chunk_requests(state: &[u8], chunk_len: u32) -> Vec<Request> {
    let total_len = state.len() as u64;
    let chunk_count = total_len.div_ceil(chunk_len as u64) as u32;
    let mut reqs = vec![Request::ChunkBegin { total_len, chunk_len, chunk_count }];
    for (seq, piece) in state.chunks(chunk_len as usize).enumerate() {
        reqs.push(Request::Chunk { seq: seq as u32, crc: crc32(piece), data: piece.to_vec() });
    }
    reqs.push(Request::ChunkEnd { total_crc: crc32(state) });
    reqs
}

/// One request/response exchange over a raw socket (no `RemoteClient`,
/// so the tests control every frame byte).
fn rpc(sock: &mut UnixStream, req: &Request) -> Response {
    let mut buf = Vec::new();
    write_frame(&mut buf, &req.encode()).unwrap();
    sock.write_all(&buf).unwrap();
    let frame = read_frame(sock).expect("server must answer").expect("with a frame");
    Response::decode(&frame).unwrap()
}

fn expect_error(resp: Response, needle: &str) {
    match resp {
        Response::Error { message } => {
            assert!(message.contains(needle), "`{needle}` not in `{message}`");
        }
        other => panic!("expected an Error mentioning `{needle}`, got {other:?}"),
    }
}

#[test]
fn chunked_upload_over_the_wire_restores_byte_exactly() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let state = donor_state(9);

    let mut sock = UnixStream::connect(&path).unwrap();
    for req in chunk_requests(&state, 7) {
        match rpc(&mut sock, &req) {
            Response::Ok => {}
            other => panic!("{req:?} got {other:?}"),
        }
    }
    assert_eq!(service.table("replay").unwrap().len(), 9);
    assert_eq!(service.checkpoint().unwrap().encode(), state, "restore must be byte-exact");

    drop(sock);
    stop_server(&path, handle);
}

#[test]
fn truncation_mid_chunk_applies_nothing_and_keeps_the_server_up() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let state = donor_state(9);
    let reqs = chunk_requests(&state, 7);

    let mut sock = UnixStream::connect(&path).unwrap();
    assert!(matches!(rpc(&mut sock, &reqs[0]), Response::Ok));
    assert!(matches!(rpc(&mut sock, &reqs[1]), Response::Ok));
    // Cut the connection in the middle of the next chunk's frame: the
    // server answers a best-effort protocol error and drops the
    // connection — and with it the staged upload.
    let mut frame = Vec::new();
    write_frame(&mut frame, &reqs[2].encode()).unwrap();
    sock.write_all(&frame[..frame.len() / 2]).unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    let mut tail = Vec::new();
    let _ = sock.read_to_end(&mut tail);
    drop(sock);

    assert_eq!(service.table("replay").unwrap().len(), 0, "no half-restored table");
    // A fresh connection starts from scratch (staging is
    // connection-local, so the dead upload did not leak into it) and a
    // complete upload still lands.
    let mut fresh = UnixStream::connect(&path).unwrap();
    expect_error(rpc(&mut fresh, &reqs[2]), "no ChunkBegin");
    for req in chunk_requests(&state, 7) {
        assert!(matches!(rpc(&mut fresh, &req), Response::Ok), "{req:?}");
    }
    assert_eq!(service.table("replay").unwrap().len(), 9);

    drop(fresh);
    stop_server(&path, handle);
}

#[test]
fn out_of_order_chunk_seq_aborts_the_upload() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let state = donor_state(9);
    let reqs = chunk_requests(&state, 7);

    let mut sock = UnixStream::connect(&path).unwrap();
    assert!(matches!(rpc(&mut sock, &reqs[0]), Response::Ok));
    // reqs[2] is chunk seq 1; the upload expects seq 0 first.
    expect_error(rpc(&mut sock, &reqs[2]), "out of order");
    // The abort discarded the staging: the now-in-order first chunk is
    // outside any upload, and the tables were never touched.
    expect_error(rpc(&mut sock, &reqs[1]), "no ChunkBegin");
    assert_eq!(service.table("replay").unwrap().len(), 0);
    // The connection itself stays up for well-formed requests.
    match rpc(&mut sock, &Request::Stats) {
        Response::Stats { tables } => assert_eq!(tables[0].len, 0),
        other => panic!("stats after abort got {other:?}"),
    }

    drop(sock);
    stop_server(&path, handle);
}

#[test]
fn crc_flip_inside_a_chunk_aborts_the_upload() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let state = donor_state(9);
    let reqs = chunk_requests(&state, 7);

    let mut sock = UnixStream::connect(&path).unwrap();
    assert!(matches!(rpc(&mut sock, &reqs[0]), Response::Ok));
    // Flip one payload byte but keep the declared per-chunk CRC. The
    // frame checksum is recomputed over the corrupted bytes (so the
    // framing layer passes) and the per-chunk CRC must catch it.
    let corrupted = match &reqs[1] {
        Request::Chunk { seq, crc, data } => {
            let mut data = data.clone();
            data[3] ^= 0x10;
            Request::Chunk { seq: *seq, crc: *crc, data }
        }
        other => panic!("expected a chunk, got {other:?}"),
    };
    expect_error(rpc(&mut sock, &corrupted), "CRC mismatch");
    assert_eq!(service.table("replay").unwrap().len(), 0, "no half-restored table");

    drop(sock);
    stop_server(&path, handle);
}

#[test]
fn oversized_chunk_and_hostile_begin_are_rejected() {
    let service = tiny_service();
    let (path, handle) = start_server(Arc::clone(&service));
    let state = donor_state(9);
    let reqs = chunk_requests(&state, 7);

    let mut sock = UnixStream::connect(&path).unwrap();
    // A chunk larger than the upload declared it would be.
    assert!(matches!(rpc(&mut sock, &reqs[0]), Response::Ok));
    let oversized = Request::Chunk { seq: 0, crc: crc32(&state), data: state.clone() };
    expect_error(rpc(&mut sock, &oversized), "upload declared");

    // A ChunkBegin whose declared geometry breaks the protocol bounds
    // is rejected at decode, before any staging allocation.
    let cap = pal_rl::remote::proto::MAX_CHUNK_LEN;
    let hostile =
        Request::ChunkBegin { total_len: 1 << 30, chunk_len: (cap + 1) as u32, chunk_count: 16 };
    expect_error(rpc(&mut sock, &hostile), "out of range");
    let total_len = state.len() as u64;
    let lying = Request::ChunkBegin { total_len, chunk_len: 7, chunk_count: 1 };
    expect_error(rpc(&mut sock, &lying), "needs");

    assert_eq!(service.table("replay").unwrap().len(), 0);
    drop(sock);
    stop_server(&path, handle);
}

#[test]
fn prop_truncated_session_requests_error_at_every_cut() {
    // The session-resumption fields (hello session ids, append
    // seq/dropped, sample seq) decode strictly: every prefix cut of a
    // valid encoding is an error, never a panic or a silent
    // misinterpretation.
    let reqs = [
        Request::Hello { rng_seed: 0x5EED, session: 41, tables: vec!["replay".into()] },
        Request::Append { actor_id: 3, seq: 17, dropped: 5, steps: vec![step(0), step(1)] },
        Request::Sample { table: "replay".into(), batch: 8, seq: 9 },
    ];
    for req in &reqs {
        let bytes = req.encode();
        // Sanity: the full encoding roundtrips.
        assert_eq!(&Request::decode(&bytes).unwrap(), req);
        let gen = UsizeIn { lo: 0, hi: bytes.len() - 1 };
        check("session-truncation", 0x5E55, 200, &gen, |&cut| {
            match Request::decode(&bytes[..cut]) {
                Err(e) => {
                    if e.to_string().is_empty() {
                        Err("error with empty message".into())
                    } else {
                        Ok(())
                    }
                }
                Ok(got) => Err(format!("cut at {cut} decoded to {got:?}")),
            }
        });
    }
}
