//! Cross-host replay mesh integration: a seeded 2-server mesh run
//! (append + sample + priority-update + checkpoint) must be
//! indistinguishable from the in-process sharded replay it mirrors —
//! per-server checkpoints byte-identical to service twins fed the same
//! lockstep schedule, priority masses identical to a
//! `ShardedPrioritizedReplay` twin with the same shard topology, and
//! exact client-vs-`Stats` accounting. A large table state must also
//! round-trip through chunked Checkpoint/Restore over TCP in bounded
//! frames.

use pal_rl::remote::{
    read_frame, write_frame, ConnectionPolicy, Endpoint, MeshSampler, MeshWriter, RemoteClient,
    ReplayServer, Request, Response,
};
use pal_rl::replay::{
    PrioritizedConfig, ReplayBuffer, SampleBatch, ShardedPrioritizedReplay, UniformReplay,
};
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimiter, ReplayService, SampleOutcome,
    ServiceState, Table, WriterStep,
};
use pal_rl::util::blob::crc32;
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 0x4D45_5348; // "MESH"
const CAP: usize = 64; // per-server table capacity == mesh stride
const ACTORS: usize = 4;
const STEPS: usize = 24; // per actor; 2 actors/server -> 48 < CAP, no eviction
const BATCH: usize = 8;
const ROUNDS: usize = 12;

fn step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32, -(i as f32)],
        action: vec![0.25],
        next_obs: vec![i as f32 + 1.0, -(i as f32) - 1.0],
        reward: (i % 7) as f32,
        done: false,
        truncated: false,
    }
}

/// One mesh member's service: a single-shard prioritized table, so the
/// 2-server mesh has exactly the shard topology of an in-process
/// `ShardedPrioritizedReplay` with `shards: 2`.
fn member_service() -> Arc<ReplayService> {
    let cfg = PrioritizedConfig {
        capacity: CAP,
        obs_dim: 2,
        act_dim: 1,
        shards: 1,
        ..PrioritizedConfig::default()
    };
    Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::new(ShardedPrioritizedReplay::new(cfg)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap(),
    )
}

/// Bind a server on `bind`, serve it on a background thread, and wait
/// until the resolved endpoint accepts connections.
fn start_on(
    service: Arc<ReplayService>,
    bind: &Endpoint,
) -> (Endpoint, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = ReplayServer::bind_endpoint(service, bind, 0).expect("bind mesh server");
    let ep = server.endpoint();
    let handle = std::thread::spawn(move || server.serve());
    for _ in 0..500 {
        if ep.dial().is_ok() {
            return (ep, handle);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server at {ep} never came up");
}

fn fresh_uds() -> Endpoint {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    Endpoint::from(std::env::temp_dir().join(format!(
        "pal_mesh_test_{}_{}.sock",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    )))
}

/// Replica of the mesh sampler's level-1 prefix scan (pick the server
/// whose mass interval contains `x`, skipping zero-mass servers). Runs
/// in f64 like the mesh's, so the lockstep replay stays exact.
fn twin_pick(masses: &[(u64, f32)], x: f64) -> Option<usize> {
    let mut sel = None;
    let mut acc = 0.0f64;
    for (k, &(_, m)) in masses.iter().enumerate() {
        let m = f64::from(m);
        if m > 0.0 {
            sel = Some(k);
            if acc + m >= x {
                break;
            }
        }
        acc += m;
    }
    sel
}

/// The full seeded drill over two already-bound servers: lockstep
/// append/sample/update against per-server twins and a sharded-topology
/// twin, then byte-identical per-server checkpoints and exact
/// accounting.
fn mesh_drill(binds: [Endpoint; 2]) {
    let services: Vec<Arc<ReplayService>> = (0..2).map(|_| member_service()).collect();
    let twins: Vec<Arc<ReplayService>> = (0..2).map(|_| member_service()).collect();

    // The in-process image of the whole mesh: same per-shard capacity,
    // same actor-affinity routing, same global index space (global
    // index = shard * CAP + local == server * stride + local).
    let cfg = PrioritizedConfig {
        capacity: 2 * CAP,
        obs_dim: 2,
        act_dim: 1,
        shards: 2,
        ..PrioritizedConfig::default()
    };
    let sharded = Arc::new(ShardedPrioritizedReplay::new(cfg));
    let sharded_service = Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::clone(&sharded) as Arc<dyn ReplayBuffer>,
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap(),
    );
    let sharded_table = sharded_service.table("replay").unwrap();

    let mut eps = Vec::new();
    let mut handles = Vec::new();
    for (service, bind) in services.iter().zip(&binds) {
        let (ep, handle) = start_on(Arc::clone(service), bind);
        eps.push(ep);
        handles.push(handle);
    }
    let policy = ConnectionPolicy::default();

    // Phase 1: appends route by actor affinity; twins and the sharded
    // image are fed the identical streams in the identical order.
    for actor in 0..ACTORS {
        let mut w = MeshWriter::connect(&eps, actor as u64, policy.clone())
            .expect("mesh writer")
            .with_batch(BATCH);
        assert_eq!(w.server(), actor % 2, "actor {actor} affinity");
        let mut tw = twins[actor % 2].writer(actor);
        let mut sw = sharded_service.writer(actor);
        for i in 0..STEPS {
            let st = step(actor * 10_000 + i);
            assert!(!w.throttled().unwrap(), "unlimited table must never throttle");
            w.append(st.clone()).unwrap();
            tw.append(st.clone());
            sw.append(st);
        }
        w.flush().unwrap();
    }

    // Phase 2: two-level sampling in lockstep. The mesh's level-1 pick
    // is replicated from the twins' advertised masses; the picked
    // twin's sampler shares its server's session RNG stream.
    let mut sampler =
        MeshSampler::connect_default(&eps, SEED, policy.clone()).expect("mesh sampler");
    assert_eq!(sampler.table(), "replay");
    assert_eq!(sampler.server_count(), 2);
    assert_eq!(sampler.stride(), CAP);
    let mut mesh_rng = Rng::new(SEED);
    let mut twin_rngs: Vec<Rng> = (0..2)
        .map(|s| Rng::new(pal_rl::remote::mesh::server_seed(SEED, s)))
        .collect();
    let twin_samplers: Vec<_> = twins.iter().map(|t| t.default_sampler()).collect();
    let mut dummy_rng = Rng::new(1); // the mesh sampler draws its own
    let mut out = SampleBatch::default();
    let mut twin_out = SampleBatch::default();
    let mut picked = [0usize; 2];
    for round in 0..ROUNDS {
        match sampler.try_sample(BATCH, &mut dummy_rng, &mut out).unwrap() {
            SampleOutcome::Sampled => {}
            other => panic!("mesh round {round} got {other:?}"),
        }
        let masses: Vec<(u64, f32)> = twins
            .iter()
            .map(|t| {
                let tab = t.table("replay").unwrap();
                (tab.len() as u64, tab.total_priority())
            })
            .collect();
        let total: f64 = masses.iter().map(|&(_, m)| f64::from(m)).sum();
        let x = mesh_rng.f64() * total;
        let sel = twin_pick(&masses, x).expect("positive mass");
        match twin_samplers[sel].try_sample(BATCH, &mut twin_rngs[sel], &mut twin_out) {
            SampleOutcome::Sampled => {}
            other => panic!("twin round {round} got {other:?}"),
        }
        let global: Vec<usize> = twin_out.indices.iter().map(|&i| i + sel * CAP).collect();
        assert_eq!(out.indices, global, "round {round} indices");
        assert_eq!(out.priorities, twin_out.priorities, "round {round} priorities");
        // Identical |TD| feedback three ways: the mesh (global
        // indices), the picked twin (local), the sharded image
        // (global — its index space IS the mesh's).
        let tds: Vec<f32> =
            (0..BATCH).map(|j| ((round * 13 + j) % 91) as f32 * 0.1 + 0.05).collect();
        sampler.update_priorities(&out.indices, &tds).unwrap();
        twin_samplers[sel].update_priorities(&twin_out.indices, &tds);
        sharded_table.update_priorities(&out.indices, &tds);
        picked[sel] += 1;
    }
    assert_eq!(picked[0] + picked[1], ROUNDS);

    // Phase 3: per-server state and accounting. Checkpoints must be
    // byte-identical to the twins; Stats must agree exactly with what
    // the client did; masses must match the sharded image shard for
    // shard.
    for (s, ep) in eps.iter().enumerate() {
        let mut client = RemoteClient::connect_endpoint(ep).unwrap();
        let twin_table = twins[s].table("replay").unwrap();
        let tables = client.stats().unwrap();
        let info = tables.iter().find(|t| t.name == "replay").unwrap();
        assert_eq!(info.len as usize, twin_table.len(), "server {s} len");
        assert_eq!(info.capacity as usize, CAP, "server {s} capacity");
        assert_eq!(info.stats, twin_table.stats_snapshot(), "server {s} accounting");
        assert_eq!(info.stats.inserts, 2 * STEPS, "server {s} inserts");
        assert_eq!(info.stats.sample_batches, picked[s], "server {s} batches");
        assert_eq!(info.stats.sampled_items, BATCH * picked[s], "server {s} items");
        assert_eq!(info.stats.priority_updates, BATCH * picked[s], "server {s} updates");

        let (mlen, mmass) = client.mass("replay").unwrap();
        assert_eq!(mlen as usize, twin_table.len(), "server {s} mass len");
        assert_eq!(mmass, twin_table.total_priority(), "server {s} mass");
        assert_eq!(mlen as usize, sharded.shard(s).len(), "shard {s} len");
        assert_eq!(mmass, sharded.shard(s).total_priority(), "shard {s} mass");

        let bytes = client.checkpoint_bytes_chunked(512).unwrap();
        assert!(bytes.len() > 512, "checkpoint must need more than one 512-byte chunk");
        assert_eq!(bytes, twins[s].checkpoint().unwrap().encode(), "server {s} checkpoint");
    }
    assert_eq!(sharded_table.len(), ACTORS * STEPS, "sharded image len");
    let mass_sum: f32 = twins.iter().map(|t| t.table("replay").unwrap().total_priority()).sum();
    assert_eq!(sharded.total_priority(), mass_sum, "sharded image total mass");

    // Phase 4: mesh-wide save/restore fans out per server and is a
    // byte-level no-op on an unchanged mesh.
    let states = sampler.checkpoint_states().unwrap();
    assert_eq!(states.len(), 2);
    sampler.restore_states(&states).unwrap();
    for (s, ep) in eps.iter().enumerate() {
        let bytes = RemoteClient::connect_endpoint(ep).unwrap().checkpoint_bytes().unwrap();
        assert_eq!(bytes, twins[s].checkpoint().unwrap().encode(), "server {s} after restore");
    }

    drop(sampler);
    for ep in &eps {
        RemoteClient::connect_endpoint(ep).unwrap().shutdown().unwrap();
    }
    for handle in handles {
        handle.join().expect("server thread").expect("serve result");
    }
}

#[test]
fn mesh_over_uds_matches_in_process_twins() {
    mesh_drill([fresh_uds(), fresh_uds()]);
}

#[test]
fn mesh_over_tcp_matches_in_process_twins() {
    mesh_drill([Endpoint::tcp("127.0.0.1:0").unwrap(), Endpoint::tcp("127.0.0.1:0").unwrap()]);
}

// ---------------------------------------------------------------------------
// Chunked streaming at scale, over TCP.
// ---------------------------------------------------------------------------

fn big_service() -> Arc<ReplayService> {
    Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::new(UniformReplay::new(2048, 8, 2)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap(),
    )
}

fn big_step(i: usize) -> WriterStep {
    let b = i as f32;
    WriterStep {
        obs: (0..8).map(|k| b + k as f32).collect(),
        action: vec![b * 0.5, -b],
        next_obs: (0..8).map(|k| b - k as f32).collect(),
        reward: b * 0.125,
        done: false,
        truncated: false,
    }
}

/// A table state hundreds of chunks long must round-trip through
/// chunked Checkpoint/Restore over TCP with every frame bounded by the
/// requested chunk size. The 1 KiB chunk is to this ~160 KiB state what
/// `MAX_CHUNK_LEN` is to a state past the 256 MiB frame cap: the stream
/// shape (header + N bounded chunks + trailer) is identical, only the
/// scale differs.
#[test]
fn big_state_round_trips_in_bounded_frames_over_tcp() {
    const CHUNK: usize = 1 << 10;
    let service = big_service();
    let mut w = service.writer(0);
    for i in 0..2048 {
        w.append(big_step(i));
    }
    let expect = service.checkpoint().unwrap().encode();
    assert!(expect.len() > 64 * CHUNK, "state must dwarf the chunk size");
    let (ep, handle) = start_on(Arc::clone(&service), &Endpoint::tcp("127.0.0.1:0").unwrap());

    // Raw dial: observe the actual frame stream, not just the
    // client-side reassembly.
    let mut raw = ep.dial().unwrap();
    let req = Request::CheckpointChunked { max_chunk: CHUNK as u32 };
    write_frame(&mut raw, &req.encode()).unwrap();
    let frame = read_frame(&mut raw).unwrap().expect("ChunkBegin frame");
    let chunk_count = match Response::decode(&frame).unwrap() {
        Response::ChunkBegin { total_len, chunk_len, chunk_count } => {
            assert_eq!(total_len as usize, expect.len());
            assert_eq!(chunk_len as usize, CHUNK);
            chunk_count
        }
        other => panic!("expected ChunkBegin, got {other:?}"),
    };
    assert!(chunk_count > 64, "a large state must stream as many chunks");
    let mut got = Vec::new();
    for seq in 0..chunk_count {
        let frame = read_frame(&mut raw).unwrap().expect("chunk frame");
        match Response::decode(&frame).unwrap() {
            Response::Chunk { seq: s, crc, data } => {
                assert_eq!(s, seq, "chunks must stream in strict sequence");
                assert!(data.len() <= CHUNK, "chunk {seq} exceeds the declared bound");
                assert_eq!(crc, crc32(&data), "chunk {seq} CRC");
                got.extend_from_slice(&data);
            }
            other => panic!("chunk {seq} got {other:?}"),
        }
    }
    match Response::decode(&read_frame(&mut raw).unwrap().expect("ChunkEnd frame")).unwrap() {
        Response::ChunkEnd { total_crc } => assert_eq!(total_crc, crc32(&got)),
        other => panic!("expected ChunkEnd, got {other:?}"),
    }
    assert_eq!(got, expect, "reassembled state differs from the served checkpoint");
    drop(raw);

    // The client-side reassembly agrees byte for byte.
    let mut client = RemoteClient::connect_endpoint(&ep).unwrap();
    assert_eq!(client.checkpoint_bytes_chunked(CHUNK).unwrap(), expect);

    // And the same state uploads through the chunked restore into a
    // fresh server, coming back byte-identical.
    let fresh = big_service();
    let (ep2, handle2) = start_on(Arc::clone(&fresh), &Endpoint::tcp("127.0.0.1:0").unwrap());
    let state = ServiceState::decode(&expect).unwrap();
    let mut client2 = RemoteClient::connect_endpoint(&ep2).unwrap();
    client2.restore_state_chunked(&state, CHUNK).unwrap();
    assert_eq!(fresh.table("replay").unwrap().len(), 2048);
    assert_eq!(client2.checkpoint_bytes_chunked(CHUNK).unwrap(), expect);

    drop(client);
    drop(client2);
    RemoteClient::connect_endpoint(&ep).unwrap().shutdown().unwrap();
    RemoteClient::connect_endpoint(&ep2).unwrap().shutdown().unwrap();
    handle.join().expect("server thread").expect("serve result");
    handle2.join().expect("server thread").expect("serve result");
}
