//! End-to-end checkpoint round-trip: a run's replay service (buffers +
//! priorities + table stats + limiter counters) and weights are saved
//! mid-flight, a FRESH service/server is built the way a restarted
//! process would build it, the state is restored, and the resumed stack
//! must (a) equal the snapshot exactly and (b) keep training with
//! identical sampling behavior.
//!
//! Corruption coverage: truncated files, flipped bytes, wrong magic,
//! version bumps and mismatched topologies must all fail cleanly with a
//! descriptive error and leave the target service untouched — never
//! panic, never half-load a table.

use pal_rl::coordinator::{
    build_service, restore_run_state, save_run_state, BufferKind, TrainConfig, WEIGHTS_FILE,
};
use pal_rl::params::{AdamConfig, ParameterServer, TargetSync};
use pal_rl::replay::{
    PrioritizedConfig, ReplayBuffer, SampleBatch, ShardedPrioritizedReplay, Transition,
};
use pal_rl::service::{
    ItemKind, RateLimitSpec, ReplayService, SampleOutcome, ServiceState, TableSpec, WriterStep,
    STATE_FILE,
};
use pal_rl::util::rng::Rng;

const OBS: usize = 3;
const ACT: usize = 2;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pal_ckpt_rt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A restart-shaped config: sharded prioritized learner table under a
/// σ=1 ratio limiter + a free-running N-step auxiliary table.
fn cfg() -> TrainConfig {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::PalKary;
    cfg.buffer_capacity = 512;
    cfg.shards = 4;
    cfg.warmup_steps = 32;
    cfg.rate_limit = RateLimitSpec::SamplesPerInsert(1.0);
    cfg.tables = vec![
        TableSpec {
            name: "replay".into(),
            kind: ItemKind::OneStep,
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
            remove: None,
        },
        TableSpec {
            name: "aux".into(),
            kind: ItemKind::NStep { n: 3, gamma: 0.99 },
            capacity: Some(256),
            alpha: None,
            beta: None,
            limit: None,
            remove: None,
        },
    ];
    cfg
}

fn svc() -> ReplayService {
    build_service(&cfg(), OBS, ACT).unwrap()
}

fn server(init: f32) -> ParameterServer {
    ParameterServer::new(vec![init; 8], AdamConfig::default(), TargetSync::None, 1)
}

/// Drive a mini training run: writer items + rate-limited sampling +
/// priority feedback.
fn drive(service: &ReplayService, steps: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let mut out = SampleBatch::default();
    let mut writer = service.writer(0);
    let sampler = service.default_sampler();
    for i in 0..steps {
        writer.append(WriterStep {
            obs: vec![i as f32; OBS],
            action: vec![0.5; ACT],
            next_obs: vec![i as f32 + 1.0; OBS],
            reward: 1.0,
            done: i % 25 == 24,
            truncated: false,
        });
        if i % 2 == 1 && sampler.try_sample(8, &mut rng, &mut out) == SampleOutcome::Sampled {
            let idx = out.indices.clone();
            let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 3.0).collect();
            sampler.update_priorities(&idx, &tds);
        }
    }
}

#[test]
fn killed_run_resumes_with_snapshot_equal_state() {
    let dir = tmpdir("resume");
    // "Run" 1: train a while, snapshot, then die (drop everything).
    {
        let service = svc();
        let server = server(0.5);
        server.push_gradient(0, 8, &[0.1; 8]);
        server.push_gradient(0, 8, &[0.1; 8]);
        drive(&service, 300, 7);
        save_run_state(&dir, &server, &service).unwrap();
    }
    // "Run" 2: a fresh process rebuilds the same config and resumes.
    let state = ServiceState::load(dir.join(STATE_FILE)).unwrap();
    let service = svc();
    let fresh = server(0.0);
    restore_run_state(&dir, &fresh, &service).unwrap();

    assert_eq!(fresh.opt_steps(), 2, "optimizer steps must survive");
    for t in service.tables() {
        let ts = state.table(t.name()).unwrap();
        // Element count.
        assert_eq!(t.len(), ts.buffer.len(), "{}", t.name());
        // Limiter counters (= samples_per_insert accounting).
        assert_eq!(t.stats_snapshot(), ts.stats, "{}", t.name());
    }
    // Total priority mass: the re-captured state must match the file.
    let recap = ServiceState::capture(&service).unwrap();
    for ts in &state.tables {
        let got = recap.table(&ts.name).unwrap().buffer.total_priority();
        let want = ts.buffer.total_priority();
        assert!(
            (got - want).abs() <= want.max(1.0) * 1e-4,
            "{}: priority mass {got} vs {want}",
            ts.name
        );
    }
    // Full state equality (rows, priorities, cursors, counters).
    assert_eq!(recap, state);

    // The resumed run keeps training and the ratio bound holds across
    // the restart: batches ≤ σ·inserts with σ = 1.
    drive(&service, 100, 8);
    let s = service.default_table().stats_snapshot();
    let before = state.table("replay").unwrap().stats;
    assert!(s.inserts > before.inserts);
    assert!(s.sample_batches >= before.sample_batches);
    assert!(s.sample_batches <= s.inserts, "{} > {}", s.sample_batches, s.inserts);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restored_sharded_buffer_samples_identically() {
    let mk = || {
        ShardedPrioritizedReplay::new(PrioritizedConfig {
            capacity: 256,
            obs_dim: 2,
            act_dim: 1,
            fanout: 16,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 4,
        })
    };
    let original = mk();
    let mut rng = Rng::new(3);
    for i in 0..200 {
        original.insert_from(i % 5, &Transition {
            obs: vec![i as f32, -(i as f32)],
            action: vec![0.1],
            next_obs: vec![i as f32 + 1.0, 0.0],
            reward: i as f32,
            done: false,
        });
    }
    // Vary priorities the way a learner does: feed TDs back for
    // sampled (hence occupied) indices.
    let mut out = SampleBatch::default();
    for _ in 0..10 {
        assert!(original.sample(32, &mut rng, &mut out));
        let idx = out.indices.clone();
        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 4.0).collect();
        original.update_priorities(&idx, &tds);
    }

    let state = original.snapshot_state().unwrap();
    let restored = mk();
    restored.restore_state(&state).unwrap();

    // Put the live tree in the same canonical (rebuilt) shape restore
    // produces, then identical seeds must draw identical batches.
    original.rebuild_trees();
    let mut rng_a = Rng::new(42);
    let mut rng_b = Rng::new(42);
    let mut out_a = SampleBatch::default();
    let mut out_b = SampleBatch::default();
    for round in 0..20 {
        assert!(original.sample(16, &mut rng_a, &mut out_a));
        assert!(restored.sample(16, &mut rng_b, &mut out_b));
        assert_eq!(out_a.indices, out_b.indices, "round {round}");
        assert_eq!(out_a.priorities, out_b.priorities, "round {round}");
        assert_eq!(out_a.is_weights, out_b.is_weights, "round {round}");
        assert_eq!(out_a.obs, out_b.obs, "round {round}");
        assert_eq!(out_a.reward, out_b.reward, "round {round}");
    }
}

#[test]
fn corrupt_and_truncated_state_files_fail_cleanly() {
    let dir = tmpdir("corrupt");
    let service = svc();
    drive(&service, 120, 5);
    let server0 = server(1.0);
    save_run_state(&dir, &server0, &service).unwrap();
    let path = dir.join(STATE_FILE);
    let good = std::fs::read(&path).unwrap();

    // Flipped byte anywhere in the payload -> crc mismatch.
    for frac in [0.3, 0.6, 0.9] {
        let mut bad = good.clone();
        let at = (bad.len() as f64 * frac) as usize;
        bad[at] ^= 0xA5;
        std::fs::write(&path, &bad).unwrap();
        let err = ServiceState::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("crc") || format!("{err:#}").contains("magic"));
    }

    // Truncation at various points -> clean error, no panic.
    for keep in [0usize, 5, 11, 40, good.len() - 5] {
        std::fs::write(&path, &good[..keep]).unwrap();
        assert!(ServiceState::load(&path).is_err(), "truncated at {keep}");
    }

    // Garbage with the right length -> magic error.
    std::fs::write(&path, vec![0x42u8; good.len()]).unwrap();
    assert!(ServiceState::load(&path).is_err());

    // A failed load never touches a service: restore_run_state against
    // the corrupt file leaves the fresh service and server untouched.
    let fresh = svc();
    let fresh_server = server(0.0);
    assert!(restore_run_state(&dir, &fresh_server, &fresh).is_err());
    assert_eq!(fresh.total_len(), 0);
    assert_eq!(fresh_server.opt_steps(), 0);
    assert_eq!(fresh_server.online_copy(), vec![0.0; 8]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn version_bump_is_a_descriptive_error_not_garbage() {
    let dir = tmpdir("version");
    let service = svc();
    drive(&service, 60, 6);
    let state = ServiceState::capture(&service).unwrap();
    let mut payload = state.encode();
    payload[0] = 2; // future format version
    pal_rl::util::blob::write_blob(
        dir.join(STATE_FILE),
        pal_rl::service::checkpoint::STATE_MAGIC,
        &payload,
    )
    .unwrap();
    let err = ServiceState::load(dir.join(STATE_FILE)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("version") && msg.contains("v2"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mismatched_topology_cannot_half_load() {
    let dir = tmpdir("topo");
    let service = svc();
    drive(&service, 120, 9);
    let server0 = server(1.0);
    save_run_state(&dir, &server0, &service).unwrap();

    // A run with different table shapes must refuse the whole state —
    // including the table that WOULD have matched.
    let mut other_cfg = cfg();
    other_cfg.tables[1].kind = ItemKind::Sequence { len: 4 };
    let other = build_service(&other_cfg, OBS, ACT).unwrap();
    let other_server = server(0.0);
    assert!(restore_run_state(&dir, &other_server, &other).is_err());
    assert_eq!(other.total_len(), 0, "no table may be half-loaded");
    assert_eq!(other_server.opt_steps(), 0);

    // Different shard count: geometry mismatch is rejected too.
    let mut sharded_cfg = cfg();
    sharded_cfg.shards = 8;
    let resharded = build_service(&sharded_cfg, OBS, ACT).unwrap();
    assert!(restore_run_state(&dir, &server(0.0), &resharded).is_err());
    assert_eq!(resharded.total_len(), 0);

    // Weights-dim mismatch: service must stay untouched as well.
    let small_server = ParameterServer::new(
        vec![0.0; 4],
        AdamConfig::default(),
        TargetSync::None,
        1,
    );
    let fresh = svc();
    assert!(restore_run_state(&dir, &small_server, &fresh).is_err());
    assert_eq!(fresh.total_len(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn periodic_snapshot_files_are_atomic_and_complete() {
    let dir = tmpdir("atomic");
    let service = svc();
    let server0 = server(0.25);
    // Overwrite the snapshot repeatedly while traffic flows — every
    // on-disk version must load cleanly (rename is atomic) and no .tmp
    // files may linger.
    for round in 0..5 {
        drive(&service, 60, round as u64);
        save_run_state(&dir, &server0, &service).unwrap();
        let loaded = ServiceState::load(dir.join(STATE_FILE)).unwrap();
        assert_eq!(loaded.total_len(), service.total_len(), "round {round}");
        assert!(!dir.join("replay_state.tmp").exists());
        assert!(!dir.join("weights.tmp").exists());
        assert!(dir.join(WEIGHTS_FILE).exists());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Full `train()` kill-and-resume, exercising the real coordinator
/// path. Requires compiled artifacts; skips gracefully without them.
#[test]
fn train_save_restore_roundtrip_with_artifacts() {
    let have = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/manifest.json"
    ))
    .exists();
    if !have {
        return;
    }
    let dir = tmpdir("train");
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.artifact_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.total_env_steps = 400;
    cfg.warmup_steps = 64;
    cfg.buffer_capacity = 4_096;
    cfg.seed = 3;
    cfg.save_state = Some(dir.clone());
    let r1 = pal_rl::coordinator::train(&cfg).expect("first run failed");

    let state = ServiceState::load(dir.join(STATE_FILE)).unwrap();
    let (name, stats) = &r1.table_stats[0];
    assert_eq!(&state.tables[0].name, name);
    assert_eq!(&state.tables[0].stats, stats, "snapshot must be the final counters");

    // Resume: the second run starts from the first run's buffers.
    cfg.save_state = None;
    cfg.restore_state = Some(dir.clone());
    let r2 = pal_rl::coordinator::train(&cfg).expect("resumed run failed");
    let (_, stats2) = &r2.table_stats[0];
    assert!(stats2.inserts > stats.inserts, "resumed run must keep the old items");
    std::fs::remove_dir_all(&dir).ok();
}

/// The committed legacy fixture (a hand-written PALSTAT1/v2 file: two
/// uniform `1step` tables, `hot` = 5 rows and `cold` = 3 rows, capacity
/// 16, obs 2 / act 1) must keep restoring under PALSTAT2 code — with a
/// FIFO remover, zeroed eviction counters and zeroed sample counts
/// defaulted in — and the restored service must keep evicting by each
/// table's CONFIGURED policy, not the advisory one in the file.
/// tools/remote_smoke.sh restores the same file into its multi-tenant
/// server, so breaking v1 forward-compat fails CI twice.
#[test]
fn committed_palstat1_fixture_keeps_restoring() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/palstat1/replay_state.bin");
    let state =
        ServiceState::load(&path).expect("the committed PALSTAT1 fixture must keep loading");
    assert_eq!(state.tables.len(), 2);
    for t in &state.tables {
        assert_eq!(
            t.remover,
            pal_rl::replay::RemoverSpec::Fifo,
            "legacy tables must decode with the FIFO default"
        );
        assert_eq!(t.stats.evict_fifo + t.stats.evict_lifo, 0);
        assert_eq!(t.stats.max_times_sampled, 0);
        for s in &t.buffer.shards {
            assert!(s.sample_counts.iter().all(|&c| c == 0), "legacy sample counts must zero");
        }
    }

    // The exact service shape the multi-tenant smoke serves this file to.
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::Uniform;
    cfg.warmup_steps = 1;
    cfg.rate_limit = RateLimitSpec::Unlimited;
    cfg.tables =
        TableSpec::parse_list("hot=1step@16,remove=lifo,cold=1step@16", cfg.gamma_nstep).unwrap();
    let svc = build_service(&cfg, 2, 1).unwrap();
    state.restore_into(&svc).expect("v1 file must restore into v2 tables");
    let hot = svc.table("hot").unwrap();
    let cold = svc.table("cold").unwrap();
    assert_eq!((hot.len(), cold.len()), (5, 3));
    assert_eq!(hot.stats_snapshot().inserts, 5);
    assert_eq!(cold.stats_snapshot().inserts, 3);

    // Overflow the restored tables: `hot` must evict by its configured
    // LIFO policy, `cold` by the FIFO default.
    let mut writer = svc.writer(0);
    for i in 0..20usize {
        writer.append(WriterStep {
            obs: vec![i as f32; 2],
            action: vec![0.5; 1],
            next_obs: vec![i as f32 + 1.0; 2],
            reward: 1.0,
            done: false,
            truncated: false,
        });
    }
    let (hot_s, cold_s) = (hot.stats_snapshot(), cold.stats_snapshot());
    assert_eq!(
        (hot.len(), hot_s.inserts, hot_s.evict_lifo, hot_s.evict_fifo),
        (16, 25, 9, 0),
        "hot: 11 fills + 9 LIFO evictions over the 5 restored rows"
    );
    assert_eq!(
        (cold.len(), cold_s.inserts, cold_s.evict_fifo, cold_s.evict_lifo),
        (16, 23, 7, 0),
        "cold: 13 fills + 7 FIFO evictions over the 3 restored rows"
    );

    // Sampling works and feeds the restored (zeroed) per-item counts.
    let sampler = svc.default_sampler();
    let mut rng = Rng::new(9);
    let mut out = SampleBatch::default();
    assert_eq!(sampler.try_sample(8, &mut rng, &mut out), SampleOutcome::Sampled);
    assert!(hot.stats_snapshot().max_times_sampled >= 1);
}
