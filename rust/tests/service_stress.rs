//! Replay-service stress: concurrent trajectory writers and samplers
//! over rate-limited tables. Verifies under real thread contention that
//! the limiter's ratio bound is exact (reserve-then-check protocol),
//! that stats stay consistent, that free-run tables never stall, and
//! that sampled rows are never torn.

use pal_rl::replay::{PrioritizedConfig, PrioritizedReplay, SampleBatch, ShardedPrioritizedReplay};
use pal_rl::service::{
    ItemKind, RateLimiter, ReplayService, SampleOutcome, SampleToInsertRatio, Table,
    WriterStep,
};
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

const OBS_DIM: usize = 4;
const ACT_DIM: usize = 1;
const BATCH: usize = 16;

fn mk_service(limiter: RateLimiter, shards: usize, capacity: usize) -> Arc<ReplayService> {
    let cfg = PrioritizedConfig {
        capacity,
        obs_dim: OBS_DIM,
        act_dim: ACT_DIM,
        fanout: 16,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards,
    };
    let buffer: Arc<dyn pal_rl::replay::ReplayBuffer> = if shards > 1 {
        Arc::new(ShardedPrioritizedReplay::new(cfg))
    } else {
        Arc::new(PrioritizedReplay::new(cfg))
    };
    Arc::new(
        ReplayService::new(vec![Table::new("replay", ItemKind::OneStep, buffer, limiter)])
            .unwrap(),
    )
}

/// Self-consistent step: obs[0] == reward, so torn batch assembly is
/// detectable from any sampled row.
fn mk_step(i: usize) -> WriterStep {
    let v = (i % 1000) as f32;
    WriterStep {
        obs: vec![v; OBS_DIM],
        action: vec![v],
        next_obs: vec![v + 1.0; OBS_DIM],
        reward: v,
        done: i % 50 == 49,
        truncated: false,
    }
}

/// W writer threads × `steps`, S sampler threads until writers finish.
/// Returns granted batches.
fn hammer(svc: &Arc<ReplayService>, writers: usize, samplers: usize, steps: usize) -> usize {
    let finished = AtomicUsize::new(0);
    let done = AtomicBool::new(false);
    let granted = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for tid in 0..writers {
            let svc = Arc::clone(svc);
            let finished = &finished;
            s.spawn(move || {
                let mut w = svc.writer(tid);
                let mut appended = 0usize;
                while appended < steps {
                    if w.throttled() {
                        std::thread::yield_now();
                        continue;
                    }
                    w.append(mk_step(appended));
                    appended += 1;
                }
                finished.fetch_add(1, Ordering::Relaxed);
            });
        }
        for tid in 0..samplers {
            let svc = Arc::clone(svc);
            let done = &done;
            let granted = &granted;
            s.spawn(move || {
                let sampler = svc.default_sampler();
                let mut rng = Rng::new(77 + tid as u64);
                let mut out = SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    match sampler.try_sample(BATCH, &mut rng, &mut out) {
                        SampleOutcome::Sampled => {
                            granted.fetch_add(1, Ordering::Relaxed);
                            // Torn-row check on every sampled transition.
                            for j in 0..out.len() {
                                assert_eq!(
                                    out.obs[j * OBS_DIM],
                                    out.reward[j],
                                    "torn row at sampled index {}",
                                    out.indices[j]
                                );
                            }
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() + 0.01).collect();
                            sampler.update_priorities(&idx, &tds);
                        }
                        _ => std::thread::yield_now(),
                    }
                }
            });
        }
        while finished.load(Ordering::Relaxed) < writers {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        done.store(true, Ordering::Relaxed);
    });
    granted.load(Ordering::Relaxed)
}

#[test]
fn ratio_bound_is_exact_under_concurrency() {
    // σ = 0.5 (one batch per two inserts), min_size 128, window wide
    // enough to keep both sides moving.
    let limiter = RateLimiter::SampleToInsertRatio(
        SampleToInsertRatio::new(0.5, 128, 256.0).unwrap(),
    );
    let svc = mk_service(limiter, 1, 8_192);
    let writers = 4;
    let steps = 2_000;
    let granted = hammer(&svc, writers, 2, steps);
    let snap = svc.default_table().stats_snapshot();
    assert_eq!(snap.inserts, writers * steps);
    assert_eq!(snap.sample_batches, granted);
    assert_eq!(snap.sampled_items, granted * BATCH);
    // The limiter invariant: granted batches never exceed
    // σ·inserts − min_diff (min_diff = σ·min_size − error_buffer here).
    let sigma = 0.5;
    let min_diff = sigma * 128.0 - 256.0;
    let bound = sigma * snap.inserts as f64 - min_diff;
    assert!(
        (granted as f64) <= bound + 1e-9,
        "ratio violated: {granted} batches vs bound {bound}"
    );
}

#[test]
fn unlimited_table_never_stalls_writers() {
    let svc = mk_service(RateLimiter::Unlimited { min_size_to_sample: 64 }, 1, 8_192);
    hammer(&svc, 4, 1, 1_500);
    let snap = svc.default_table().stats_snapshot();
    assert_eq!(snap.inserts, 4 * 1_500);
    assert_eq!(snap.insert_stalls, 0, "free-run table must never stall inserts");
    assert_eq!(svc.default_table().len(), (4 * 1_500).min(8_192));
}

#[test]
fn sharded_table_keeps_invariants_through_service_path() {
    // Writers with distinct actor ids exercise the sharded buffer's
    // affinity routing through the writer handle.
    let limiter = RateLimiter::SampleToInsertRatio(
        SampleToInsertRatio::new(1.0, 128, 512.0).unwrap(),
    );
    let svc = mk_service(limiter, 4, 8_192);
    let granted = hammer(&svc, 4, 2, 2_000);
    assert!(granted > 0, "samplers starved on a sharded table");
    let snap = svc.default_table().stats_snapshot();
    assert_eq!(snap.inserts, 8_000);
    assert_eq!(snap.priority_updates, granted * BATCH);
    assert_eq!(svc.default_table().len(), 8_000.min(8_192));
}

#[test]
fn writers_throttle_but_make_progress_when_samplers_lag() {
    // σ = 4 with a narrow window: writers must repeatedly stall and
    // resume, but the run must complete and record the stalls.
    let limiter = RateLimiter::SampleToInsertRatio(
        SampleToInsertRatio::new(4.0, 64, 256.0).unwrap(),
    );
    let svc = mk_service(limiter, 1, 4_096);
    hammer(&svc, 2, 1, 1_000);
    let snap = svc.default_table().stats_snapshot();
    assert_eq!(snap.inserts, 2_000);
    assert!(
        snap.insert_stalls > 0,
        "a σ=4 limiter must throttle writers at least once"
    );
}
