//! Multi-client round-trip tests for the remote replay front-end: a
//! server thread plus N writer / M sampler clients, asserting
//! sampled-batch validity (no zero-priority items), exact
//! sample-to-insert accounting across the wire, byte-identical
//! checkpoints against an equivalent in-process run, and seeded
//! sampling equivalence with the in-process `SamplerHandle`.

mod common;

use common::{start_server, stop_server};
use pal_rl::coordinator::{build_service, BufferKind, TrainConfig};
use pal_rl::remote::{RemoteClient, RemoteSampler, RemoteWriter};
use pal_rl::replay::SampleBatch;
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, RateLimitSpec, ReplayService, SampleOutcome,
    ServiceState, TableSpec, WriterStep,
};
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const OBS: usize = 3;
const ACT: usize = 1;

fn step(tag: usize, i: usize) -> WriterStep {
    WriterStep {
        obs: vec![tag as f32, i as f32, 0.5],
        action: vec![i as f32 * 0.1],
        next_obs: vec![tag as f32, i as f32 + 1.0, 0.5],
        reward: (i % 7) as f32,
        done: i % 25 == 24,
        truncated: false,
    }
}

/// One sharded prioritized `replay` table (1-step) under the given
/// rate-limit spec — the learner-table shape real runs use.
fn cfg(rate_limit: RateLimitSpec, warmup: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::PalKary;
    cfg.buffer_capacity = 4_096;
    cfg.shards = 4;
    cfg.warmup_steps = warmup;
    cfg.rate_limit = rate_limit;
    cfg.tables = TableSpec::parse_list("replay=1step", cfg.gamma_nstep).unwrap();
    cfg
}

#[test]
fn soak_n_writers_m_samplers_exact_accounting_no_zero_priorities() {
    const WRITERS: usize = 3;
    const SAMPLERS: usize = 2;
    const STEPS_EACH: usize = 400;
    const BATCH: usize = 8;

    let service = Arc::new(
        build_service(&cfg(RateLimitSpec::SamplesPerInsert(1.0), 32), OBS, ACT).unwrap(),
    );
    let (path, handle) = start_server(Arc::clone(&service));

    let done = AtomicBool::new(false);
    let batches_drawn = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let mut worker_handles = Vec::new();
        for w in 0..WRITERS {
            let path = path.clone();
            worker_handles.push(s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64).expect("writer connect");
                let wait = |writer: &mut RemoteWriter| {
                    let mut spins = 0u32;
                    while writer.throttled().expect("throttled rpc") {
                        spins += 1;
                        assert!(spins < 60_000, "writer {w} stalled >60s");
                        std::thread::sleep(Duration::from_millis(1));
                    }
                };
                for i in 0..STEPS_EACH {
                    wait(&mut writer);
                    writer.append(step(w, i)).expect("append rpc");
                }
                // Drain: the limiter may have stalled the final step.
                wait(&mut writer);
            }));
        }
        for m in 0..SAMPLERS {
            let path = path.clone();
            let done = &done;
            let batches_drawn = &batches_drawn;
            s.spawn(move || {
                let mut sampler =
                    RemoteSampler::connect_default(&path, 1_000 + m as u64).expect("sampler");
                let mut rng = Rng::new(m as u64);
                let mut out = SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    match sampler.try_sample(BATCH, &mut rng, &mut out).expect("sample rpc") {
                        SampleOutcome::Sampled => {
                            assert_eq!(out.len(), BATCH);
                            // Lazy-writing guard: a half-written row has
                            // zero priority and must never be sampled,
                            // in-process or over the wire.
                            assert!(
                                out.priorities.iter().all(|&p| p > 0.0),
                                "sampled a zero-priority item over the wire"
                            );
                            batches_drawn.fetch_add(1, Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0 + 0.01).collect();
                            sampler.update_priorities(&idx, &tds).expect("update rpc");
                        }
                        _ => std::thread::yield_now(),
                    }
                }
            });
        }
        // Join writers and set `done` BEFORE asserting, so a failed
        // writer cannot leave the samplers spinning forever while the
        // scope waits on them.
        let results: Vec<_> = worker_handles.into_iter().map(|h| h.join()).collect();
        done.store(true, Ordering::Relaxed);
        for r in results {
            r.expect("writer thread");
        }
    });

    // Exact accounting: the server's counters equal the clients' tallies.
    let batches = batches_drawn.load(Ordering::Relaxed);
    let stats = RemoteClient::connect(&path).unwrap().stats().unwrap();
    assert_eq!(stats.len(), 1);
    let t = &stats[0].stats;
    assert_eq!(
        t.inserts,
        WRITERS * STEPS_EACH,
        "every appended step must be recorded exactly once"
    );
    assert_eq!(t.sample_batches, batches, "granted batches must match client tally");
    assert_eq!(t.sampled_items, BATCH * batches);
    assert_eq!(t.priority_updates, BATCH * batches);
    // σ=1 ratio bound over the whole run.
    assert!(
        t.sample_batches <= t.inserts,
        "ratio bound violated: {} batches vs {} inserts",
        t.sample_batches,
        t.inserts
    );
    // And the server-side table really holds the data.
    assert_eq!(service.table("replay").unwrap().len(), WRITERS * STEPS_EACH);

    stop_server(&path, handle);
}

#[test]
fn concurrent_remote_writers_checkpoint_byte_identical_to_in_process_run() {
    // 4 writers with distinct actor ids on a 4-shard table: affinity
    // routing gives each shard exactly one writer's items in order, so
    // the final state is deterministic even under concurrency — and
    // must equal, byte for byte, the same traffic applied in-process.
    const WRITERS: usize = 4;
    const STEPS_EACH: usize = 200;

    let make = || {
        Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 16), OBS, ACT).unwrap())
    };
    let served = make();
    let (path, handle) = start_server(Arc::clone(&served));

    std::thread::scope(|s| {
        for w in 0..WRITERS {
            let path = path.clone();
            s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64).expect("connect");
                for i in 0..STEPS_EACH {
                    assert!(!writer.throttled().expect("rpc"), "unlimited table throttled");
                    writer.append(step(w, i)).expect("append");
                }
            });
        }
    });
    let remote_bytes = RemoteClient::connect(&path).unwrap().checkpoint_bytes().unwrap();
    stop_server(&path, handle);

    // The equivalent in-process run: same steps, one actor at a time.
    let twin = make();
    for w in 0..WRITERS {
        let mut writer = twin.writer(w);
        for i in 0..STEPS_EACH {
            writer.append(step(w, i));
        }
    }
    let twin_bytes = ServiceState::capture(&twin).unwrap().encode();
    assert_eq!(remote_bytes.len(), twin_bytes.len(), "checkpoint sizes differ");
    assert!(
        remote_bytes == twin_bytes,
        "remote checkpoint differs from the in-process twin (first diff at byte {})",
        remote_bytes
            .iter()
            .zip(&twin_bytes)
            .position(|(a, b)| a != b)
            .unwrap_or(0)
    );
}

#[test]
fn seeded_remote_sample_update_loop_equals_in_process_sampler() {
    const SEED: u64 = 0xE0_11AB;
    const ROUNDS: usize = 50;
    const BATCH: usize = 16;

    // Two identically built and identically filled services...
    let fill = |svc: &ReplayService| {
        let mut w = svc.writer(0);
        for i in 0..300 {
            w.append(step(0, i));
        }
    };
    let served = Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap());
    let local = build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap();
    fill(&served);
    fill(&local);

    // ...one behind the socket, one sampled in-process with the same
    // seed the remote connection's server-side RNG gets.
    let (path, handle) = start_server(Arc::clone(&served));
    let mut remote = RemoteSampler::connect(&path, "replay", SEED).unwrap();
    let local_sampler = local.default_sampler();
    let mut local_rng = Rng::new(SEED);

    let mut unused = Rng::new(9); // the remote side ignores this RNG
    let mut remote_out = SampleBatch::default();
    let mut local_out = SampleBatch::default();
    for round in 0..ROUNDS {
        let r = remote.try_sample(BATCH, &mut unused, &mut remote_out).unwrap();
        let l = local_sampler.try_sample(BATCH, &mut local_rng, &mut local_out);
        assert_eq!(r, l, "round {round}: outcomes diverged");
        assert_eq!(r, SampleOutcome::Sampled, "round {round} must sample");
        assert_eq!(
            remote_out.indices, local_out.indices,
            "round {round}: index trajectories diverged"
        );
        assert_eq!(
            remote_out.priorities, local_out.priorities,
            "round {round}: priorities diverged"
        );
        assert_eq!(
            remote_out.is_weights, local_out.is_weights,
            "round {round}: importance weights diverged"
        );
        // Identical feedback keeps the two tables in lockstep.
        let tds: Vec<f32> = (0..BATCH)
            .map(|j| ((round * 13 + j) % 31) as f32 * 0.2 + 0.1)
            .collect();
        remote.update_priorities(&remote_out.indices, &tds).unwrap();
        local_sampler.update_priorities(&local_out.indices, &tds);
    }

    // After the lockstep loop the full states still agree.
    let remote_state = RemoteClient::connect(&path).unwrap().checkpoint_state().unwrap();
    let local_state = ServiceState::capture(&local).unwrap();
    assert_eq!(remote_state, local_state);

    drop(remote);
    stop_server(&path, handle);
}
