//! Multi-client round-trip tests for the remote replay front-end: a
//! server thread plus N writer / M sampler clients, asserting
//! sampled-batch validity (no zero-priority items), exact
//! sample-to-insert accounting across the wire, byte-identical
//! checkpoints against an equivalent in-process run, and seeded
//! sampling equivalence with the in-process `SamplerHandle`.

mod common;

use common::{start_server, stop_server};
use pal_rl::coordinator::{build_service, BufferKind, TrainConfig};
use pal_rl::remote::{RemoteClient, RemoteSampler, RemoteWriter};
use pal_rl::replay::SampleBatch;
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, RateLimitSpec, ReplayService, SampleOutcome,
    ServiceState, TableSpec, WriterStep,
};
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const OBS: usize = 3;
const ACT: usize = 1;

fn step(tag: usize, i: usize) -> WriterStep {
    WriterStep {
        obs: vec![tag as f32, i as f32, 0.5],
        action: vec![i as f32 * 0.1],
        next_obs: vec![tag as f32, i as f32 + 1.0, 0.5],
        reward: (i % 7) as f32,
        done: i % 25 == 24,
        truncated: false,
    }
}

/// One sharded prioritized `replay` table (1-step) under the given
/// rate-limit spec — the learner-table shape real runs use.
fn cfg(rate_limit: RateLimitSpec, warmup: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.buffer = BufferKind::PalKary;
    cfg.buffer_capacity = 4_096;
    cfg.shards = 4;
    cfg.warmup_steps = warmup;
    cfg.rate_limit = rate_limit;
    cfg.tables = TableSpec::parse_list("replay=1step", cfg.gamma_nstep).unwrap();
    cfg
}

#[test]
fn soak_n_writers_m_samplers_exact_accounting_no_zero_priorities() {
    const WRITERS: usize = 3;
    const SAMPLERS: usize = 2;
    const STEPS_EACH: usize = 400;
    const BATCH: usize = 8;

    let service = Arc::new(
        build_service(&cfg(RateLimitSpec::SamplesPerInsert(1.0), 32), OBS, ACT).unwrap(),
    );
    let (path, handle) = start_server(Arc::clone(&service));

    let done = AtomicBool::new(false);
    let batches_drawn = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let mut worker_handles = Vec::new();
        for w in 0..WRITERS {
            let path = path.clone();
            worker_handles.push(s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64).expect("writer connect");
                let wait = |writer: &mut RemoteWriter| {
                    let mut spins = 0u32;
                    while writer.throttled().expect("throttled rpc") {
                        spins += 1;
                        assert!(spins < 60_000, "writer {w} stalled >60s");
                        std::thread::sleep(Duration::from_millis(1));
                    }
                };
                for i in 0..STEPS_EACH {
                    wait(&mut writer);
                    writer.append(step(w, i)).expect("append rpc");
                }
                // Drain: the limiter may have stalled the final step.
                wait(&mut writer);
            }));
        }
        for m in 0..SAMPLERS {
            let path = path.clone();
            let done = &done;
            let batches_drawn = &batches_drawn;
            s.spawn(move || {
                let mut sampler =
                    RemoteSampler::connect_default(&path, 1_000 + m as u64).expect("sampler");
                let mut rng = Rng::new(m as u64);
                let mut out = SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    match sampler.try_sample(BATCH, &mut rng, &mut out).expect("sample rpc") {
                        SampleOutcome::Sampled => {
                            assert_eq!(out.len(), BATCH);
                            // Lazy-writing guard: a half-written row has
                            // zero priority and must never be sampled,
                            // in-process or over the wire.
                            assert!(
                                out.priorities.iter().all(|&p| p > 0.0),
                                "sampled a zero-priority item over the wire"
                            );
                            batches_drawn.fetch_add(1, Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0 + 0.01).collect();
                            sampler.update_priorities(&idx, &tds).expect("update rpc");
                        }
                        _ => std::thread::yield_now(),
                    }
                }
            });
        }
        // Join writers and set `done` BEFORE asserting, so a failed
        // writer cannot leave the samplers spinning forever while the
        // scope waits on them.
        let results: Vec<_> = worker_handles.into_iter().map(|h| h.join()).collect();
        done.store(true, Ordering::Relaxed);
        for r in results {
            r.expect("writer thread");
        }
    });

    // Exact accounting: the server's counters equal the clients' tallies.
    let batches = batches_drawn.load(Ordering::Relaxed);
    let stats = RemoteClient::connect(&path).unwrap().stats().unwrap();
    assert_eq!(stats.len(), 1);
    let t = &stats[0].stats;
    assert_eq!(
        t.inserts,
        WRITERS * STEPS_EACH,
        "every appended step must be recorded exactly once"
    );
    assert_eq!(t.sample_batches, batches, "granted batches must match client tally");
    assert_eq!(t.sampled_items, BATCH * batches);
    assert_eq!(t.priority_updates, BATCH * batches);
    // σ=1 ratio bound over the whole run.
    assert!(
        t.sample_batches <= t.inserts,
        "ratio bound violated: {} batches vs {} inserts",
        t.sample_batches,
        t.inserts
    );
    // And the server-side table really holds the data.
    assert_eq!(service.table("replay").unwrap().len(), WRITERS * STEPS_EACH);

    stop_server(&path, handle);
}

#[test]
fn concurrent_remote_writers_checkpoint_byte_identical_to_in_process_run() {
    // 4 writers with distinct actor ids on a 4-shard table: affinity
    // routing gives each shard exactly one writer's items in order, so
    // the final state is deterministic even under concurrency — and
    // must equal, byte for byte, the same traffic applied in-process.
    const WRITERS: usize = 4;
    const STEPS_EACH: usize = 200;

    let make = || {
        Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 16), OBS, ACT).unwrap())
    };
    let served = make();
    let (path, handle) = start_server(Arc::clone(&served));

    std::thread::scope(|s| {
        for w in 0..WRITERS {
            let path = path.clone();
            s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64).expect("connect");
                for i in 0..STEPS_EACH {
                    assert!(!writer.throttled().expect("rpc"), "unlimited table throttled");
                    writer.append(step(w, i)).expect("append");
                }
            });
        }
    });
    let remote_bytes = RemoteClient::connect(&path).unwrap().checkpoint_bytes().unwrap();
    stop_server(&path, handle);

    // The equivalent in-process run: same steps, one actor at a time.
    let twin = make();
    for w in 0..WRITERS {
        let mut writer = twin.writer(w);
        for i in 0..STEPS_EACH {
            writer.append(step(w, i));
        }
    }
    let twin_bytes = ServiceState::capture(&twin).unwrap().encode();
    assert_eq!(remote_bytes.len(), twin_bytes.len(), "checkpoint sizes differ");
    assert!(
        remote_bytes == twin_bytes,
        "remote checkpoint differs from the in-process twin (first diff at byte {})",
        remote_bytes
            .iter()
            .zip(&twin_bytes)
            .position(|(a, b)| a != b)
            .unwrap_or(0)
    );
}

#[test]
fn seeded_remote_sample_update_loop_equals_in_process_sampler() {
    const SEED: u64 = 0xE0_11AB;
    const ROUNDS: usize = 50;
    const BATCH: usize = 16;

    // Two identically built and identically filled services...
    let fill = |svc: &ReplayService| {
        let mut w = svc.writer(0);
        for i in 0..300 {
            w.append(step(0, i));
        }
    };
    let served = Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap());
    let local = build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap();
    fill(&served);
    fill(&local);

    // ...one behind the socket, one sampled in-process with the same
    // seed the remote connection's server-side RNG gets.
    let (path, handle) = start_server(Arc::clone(&served));
    let mut remote = RemoteSampler::connect(&path, "replay", SEED).unwrap();
    let local_sampler = local.default_sampler();
    let mut local_rng = Rng::new(SEED);

    let mut unused = Rng::new(9); // the remote side ignores this RNG
    let mut remote_out = SampleBatch::default();
    let mut local_out = SampleBatch::default();
    for round in 0..ROUNDS {
        let r = remote.try_sample(BATCH, &mut unused, &mut remote_out).unwrap();
        let l = local_sampler.try_sample(BATCH, &mut local_rng, &mut local_out);
        assert_eq!(r, l, "round {round}: outcomes diverged");
        assert_eq!(r, SampleOutcome::Sampled, "round {round} must sample");
        assert_eq!(
            remote_out.indices, local_out.indices,
            "round {round}: index trajectories diverged"
        );
        assert_eq!(
            remote_out.priorities, local_out.priorities,
            "round {round}: priorities diverged"
        );
        assert_eq!(
            remote_out.is_weights, local_out.is_weights,
            "round {round}: importance weights diverged"
        );
        // Identical feedback keeps the two tables in lockstep.
        let tds: Vec<f32> = (0..BATCH)
            .map(|j| ((round * 13 + j) % 31) as f32 * 0.2 + 0.1)
            .collect();
        remote.update_priorities(&remote_out.indices, &tds).unwrap();
        local_sampler.update_priorities(&local_out.indices, &tds);
    }

    // After the lockstep loop the full states still agree.
    let remote_state = RemoteClient::connect(&path).unwrap().checkpoint_state().unwrap();
    let local_state = ServiceState::capture(&local).unwrap();
    assert_eq!(remote_state, local_state);

    drop(remote);
    stop_server(&path, handle);
}

#[test]
fn batched_writer_checkpoint_byte_identical_and_sends_each_step_once() {
    // Batched appends (16 steps per RPC) against the same 4-shard
    // affinity layout: the server must end up byte-identical to the
    // in-process twin, and the wire must carry every step exactly once
    // (no re-encodes without a stall).
    const WRITERS: usize = 4;
    const STEPS_EACH: usize = 200;
    const BATCH: usize = 16;

    let make = || {
        Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 16), OBS, ACT).unwrap())
    };
    let served = make();
    let (path, handle) = start_server(Arc::clone(&served));

    std::thread::scope(|s| {
        for w in 0..WRITERS {
            let path = path.clone();
            s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64)
                    .expect("connect")
                    .with_batch(BATCH);
                for i in 0..STEPS_EACH {
                    assert!(!writer.throttled().expect("rpc"), "unlimited table throttled");
                    writer.append(step(w, i)).expect("append");
                }
                // STEPS_EACH is not a BATCH multiple in general; the
                // tail must land before the checkpoint.
                assert_eq!(writer.flush().expect("flush"), 0, "unlimited flush left a tail");
                assert_eq!(
                    writer.wire_steps_sent(),
                    STEPS_EACH as u64,
                    "a stall-free batched writer must encode each step exactly once"
                );
            });
        }
    });
    let remote_bytes = RemoteClient::connect(&path).unwrap().checkpoint_bytes().unwrap();
    stop_server(&path, handle);

    let twin = make();
    for w in 0..WRITERS {
        let mut writer = twin.writer(w);
        for i in 0..STEPS_EACH {
            writer.append(step(w, i));
        }
    }
    let twin_bytes = ServiceState::capture(&twin).unwrap().encode();
    assert!(
        remote_bytes == twin_bytes,
        "batched-append checkpoint differs from the in-process twin (first diff at byte {})",
        remote_bytes
            .iter()
            .zip(&twin_bytes)
            .position(|(a, b)| a != b)
            .unwrap_or(0)
    );
}

#[test]
fn seeded_prefetch_loop_bit_identical_to_in_process_sampler() {
    // The pipelined sampler keeps one batch in flight behind every
    // priority update; with no concurrent appends, its draws must stay
    // bit-identical to a plain in-process SamplerHandle on the same
    // seed, and the trailing prefetch must be drainable without losing
    // the granted batch.
    const SEED: u64 = 0xF1_7EC4;
    const ROUNDS: usize = 40;
    const BATCH: usize = 16;

    let fill = |svc: &ReplayService| {
        let mut w = svc.writer(0);
        for i in 0..300 {
            w.append(step(0, i));
        }
    };
    let served = Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap());
    let local = build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap();
    fill(&served);
    fill(&local);

    let (path, handle) = start_server(Arc::clone(&served));
    let mut remote = RemoteSampler::connect(&path, "replay", SEED).unwrap().with_prefetch(true);
    let local_sampler = local.default_sampler();
    let mut local_rng = Rng::new(SEED);

    let mut unused = Rng::new(9);
    let mut remote_out = SampleBatch::default();
    let mut local_out = SampleBatch::default();
    for round in 0..ROUNDS {
        let r = remote.try_sample(BATCH, &mut unused, &mut remote_out).unwrap();
        let l = local_sampler.try_sample(BATCH, &mut local_rng, &mut local_out);
        assert_eq!(r, l, "round {round}: outcomes diverged");
        assert_eq!(r, SampleOutcome::Sampled, "round {round} must sample");
        assert_eq!(
            remote_out.indices, local_out.indices,
            "round {round}: prefetched index trajectory diverged"
        );
        assert_eq!(
            remote_out.priorities, local_out.priorities,
            "round {round}: priorities diverged"
        );
        let tds: Vec<f32> = (0..BATCH)
            .map(|j| ((round * 13 + j) % 31) as f32 * 0.2 + 0.1)
            .collect();
        remote.update_priorities(&remote_out.indices, &tds).unwrap();
        local_sampler.update_priorities(&local_out.indices, &tds);
    }

    // Drain the trailing prefetch and mirror it locally so counters
    // (part of the checkpoint) stay equal; then the full states must
    // still agree bit for bit.
    assert_eq!(remote.drain().unwrap(), Some(SampleOutcome::Sampled));
    assert_eq!(
        local_sampler.try_sample(BATCH, &mut local_rng, &mut local_out),
        SampleOutcome::Sampled
    );
    let remote_state = RemoteClient::connect(&path).unwrap().checkpoint_state().unwrap();
    let local_state = ServiceState::capture(&local).unwrap();
    assert_eq!(remote_state, local_state);

    drop(remote);
    stop_server(&path, handle);
}

#[test]
fn would_stall_mid_pipeline_loses_and_duplicates_nothing() {
    // A σ=1 ratio limiter denies the pipeline's in-flight prefetch at
    // some point; the stall must surface as a clean Throttled, the
    // pipeline must resume after more inserts, and at the end the
    // server's granted-batch counter must equal the client's tally
    // exactly (nothing lost, nothing double-counted).
    const BATCH: usize = 8;
    const TARGET_BATCHES: usize = 60;

    let service = Arc::new(
        build_service(&cfg(RateLimitSpec::SamplesPerInsert(1.0), 16), OBS, ACT).unwrap(),
    );
    let (path, handle) = start_server(Arc::clone(&service));

    // Seed the table past warmup; σ=1 then allows ~`inserts` batches.
    let mut feeder = service.writer(0);
    let mut fed = 0usize;
    for _ in 0..40 {
        feeder.append(step(0, fed));
        fed += 1;
    }

    let mut sampler = RemoteSampler::connect(&path, "replay", 0xBEEF).unwrap().with_prefetch(true);
    let mut rng = Rng::new(1);
    let mut out = SampleBatch::default();
    let mut granted = 0u64;
    let mut updates = 0u64;
    let mut throttles = 0u64;
    let mut guard = 0usize;
    while granted < TARGET_BATCHES as u64 {
        guard += 1;
        assert!(guard < 10_000, "pipeline wedged: {granted} batches after {guard} polls");
        match sampler.try_sample(BATCH, &mut rng, &mut out).unwrap() {
            SampleOutcome::Sampled => {
                granted += 1;
                assert!(out.priorities.iter().all(|&p| p > 0.0));
                let tds: Vec<f32> = out.indices.iter().map(|_| 1.0).collect();
                sampler.update_priorities(&out.indices, &tds).unwrap();
                updates += 1;
            }
            SampleOutcome::Throttled | SampleOutcome::NotEnoughData => {
                // The denial that ended the pipeline; open the window
                // and let the next try_sample start a fresh request.
                throttles += 1;
                for _ in 0..8 {
                    while feeder.throttled() {
                        std::thread::yield_now();
                    }
                    feeder.append(step(0, fed));
                    fed += 1;
                }
            }
        }
    }
    assert!(throttles > 0, "the limiter never stalled the pipeline — test shape broken");

    // Drain the trailing prefetch; if it was granted it counts.
    if sampler.drain().unwrap() == Some(SampleOutcome::Sampled) {
        granted += 1;
    }
    let stats = RemoteClient::connect(&path).unwrap().stats().unwrap();
    let t = &stats[0].stats;
    assert_eq!(
        t.sample_batches as u64, granted,
        "granted batches diverged from the client tally (lost or duplicated batch)"
    );
    assert_eq!(t.sampled_items as u64, granted * BATCH as u64);
    assert_eq!(t.priority_updates as u64, updates * BATCH as u64);
    assert!(t.sample_stalls as u64 >= throttles, "server must have recorded the stalls");

    drop(sampler);
    stop_server(&path, handle);
}

#[test]
fn consecutive_updates_stash_prefetches_in_order_without_loss() {
    // A caller that fires several update_priorities without sampling in
    // between forces the pipeline to drain in-flight responses out of
    // order; every granted batch must still be handed back (in order)
    // and the server accounting must stay exact.
    const BATCH: usize = 4;
    let service = Arc::new(build_service(&cfg(RateLimitSpec::Unlimited, 1), OBS, ACT).unwrap());
    let (path, handle) = start_server(Arc::clone(&service));
    let mut feeder = service.writer(0);
    for i in 0..64 {
        feeder.append(step(0, i));
    }

    let mut sampler = RemoteSampler::connect(&path, "replay", 5).unwrap().with_prefetch(true);
    let mut rng = Rng::new(5);
    let mut out = SampleBatch::default();
    assert_eq!(sampler.try_sample(BATCH, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
    let ones = vec![1.0f32; BATCH];
    // Three consecutive updates: the first arms the prefetch, each
    // further one drains the previous in-flight batch into the stash.
    sampler.update_priorities(&out.indices, &ones).unwrap();
    sampler.update_priorities(&out.indices, &ones).unwrap();
    sampler.update_priorities(&out.indices, &ones).unwrap();
    // Two stashed batches + one live in-flight + the explicit first
    // draw = four granted batches, all retrievable.
    for k in 0..3 {
        assert_eq!(
            sampler.try_sample(BATCH, &mut rng, &mut out).unwrap(),
            SampleOutcome::Sampled,
            "stashed/inflight batch {k} was lost"
        );
        assert_eq!(out.len(), BATCH);
    }
    assert_eq!(sampler.drain().unwrap(), None, "pipeline fully consumed");

    let stats = RemoteClient::connect(&path).unwrap().stats().unwrap();
    assert_eq!(stats[0].stats.sample_batches, 4, "granted batches must match draws exactly");
    assert_eq!(stats[0].stats.priority_updates, 3 * BATCH);

    drop(sampler);
    stop_server(&path, handle);
}

#[test]
fn stalled_writer_flush_is_chunked_not_quadratic() {
    // A long limiter stall with a deep pending queue: every retry may
    // re-encode at most one chunk, so total wire traffic stays
    // O(steps + retries · batch). The pre-fix writer re-sent the WHOLE
    // backlog every retry — O(steps²) on this shape.
    const STEPS: usize = 60;
    const BATCH: usize = 8;

    // σ=1, warmup 1 → drift window [0, 2]: at most 2 inserts ahead of
    // granted batches, so the backlog drains one insert per sample.
    let service = Arc::new(
        build_service(&cfg(RateLimitSpec::SamplesPerInsert(1.0), 1), OBS, ACT).unwrap(),
    );
    let (path, handle) = start_server(Arc::clone(&service));

    let mut writer = RemoteWriter::connect(&path, 0).unwrap().with_batch(BATCH);
    for i in 0..STEPS {
        // Deliberately NOT polling throttled(): the queue must absorb
        // a producer that runs ahead of the limiter.
        writer.append(step(0, i)).unwrap();
    }
    assert!(writer.pending_len() > 0, "the limiter never stalled — test shape broken");

    let mut sampler = RemoteSampler::connect(&path, "replay", 3).unwrap();
    let mut rng = Rng::new(3);
    let mut out = SampleBatch::default();
    let mut guard = 0usize;
    while writer.flush().unwrap() > 0 {
        guard += 1;
        assert!(guard < 1_000, "stalled backlog never drained");
        // One granted batch opens one insert of drift headroom.
        let _ = sampler.try_sample(2, &mut rng, &mut out).unwrap();
    }
    assert_eq!(service.table("replay").unwrap().len(), STEPS);
    let bound = (STEPS * BATCH) as u64;
    assert!(
        writer.wire_steps_sent() <= bound,
        "stall retries re-encoded {} steps for {STEPS} appends (chunk bound {bound}) — \
         quadratic resend regression",
        writer.wire_steps_sent()
    );

    drop(writer);
    drop(sampler);
    stop_server(&path, handle);
}
