//! Property-based tests on the replay service's trajectory writers:
//! the N-step correctness claims — an item's reward is exactly the
//! discounted fold of its underlying 1-step rewards, and episode
//! boundaries (terminal or truncated) never leak across items — plus
//! the 1-step writer's byte-for-byte equivalence with the legacy
//! direct-insert path.

use pal_rl::replay::{ReplayBuffer, SampleBatch, Transition};
use pal_rl::service::{ItemKind, RateLimiter, Table, TableSpec, TrajectoryWriter, WriterStep};
use pal_rl::util::prop::{check, Gen, Pair, UsizeIn};
use pal_rl::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Capture buffer: records every inserted item in order so tests can
/// inspect exactly what a writer emitted. Sampling is unsupported.
struct RecordingBuffer {
    items: Mutex<Vec<Transition>>,
}

impl RecordingBuffer {
    fn new() -> Self {
        Self { items: Mutex::new(Vec::new()) }
    }
}

impl ReplayBuffer for RecordingBuffer {
    fn name(&self) -> &'static str {
        "recording"
    }

    fn capacity(&self) -> usize {
        usize::MAX
    }

    fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    fn insert(&self, t: &Transition) {
        self.items.lock().unwrap().push(t.clone());
    }

    fn sample(&self, _batch: usize, _rng: &mut Rng, _out: &mut SampleBatch) -> bool {
        false
    }

    fn update_priorities(&self, _indices: &[usize], _td_abs: &[f32]) {}
}

/// A writer + its recording table for one test run.
fn recording_writer(kind: ItemKind) -> (TrajectoryWriter, Arc<RecordingBuffer>) {
    let rec = Arc::new(RecordingBuffer::new());
    let table = Arc::new(Table::new(
        "rec",
        kind,
        Arc::clone(&rec) as Arc<dyn ReplayBuffer>,
        RateLimiter::Unlimited { min_size_to_sample: 1 },
    ));
    (TrajectoryWriter::new(0, vec![table]), rec)
}

#[derive(Clone, Debug)]
struct Episode {
    rewards: Vec<f32>,
    /// true = real terminal, false = time-limit truncation.
    terminal: bool,
}

#[derive(Clone, Debug)]
struct Case {
    n: usize,
    gamma: f32,
    episodes: Vec<Episode>,
}

/// Random multi-episode N-step cases with shrinking toward fewer /
/// shorter episodes.
struct CaseGen;

impl Gen for CaseGen {
    type Value = Case;

    fn generate(&self, rng: &mut Rng) -> Case {
        let n = 1 + rng.below_usize(5);
        let gamma = rng.range_f32(0.5, 1.0);
        let n_eps = 1 + rng.below_usize(3);
        let episodes = (0..n_eps)
            .map(|_| {
                let len = 1 + rng.below_usize(20);
                Episode {
                    rewards: (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
                    terminal: rng.chance(0.5),
                }
            })
            .collect();
        Case { n, gamma, episodes }
    }

    fn shrink(&self, v: &Case) -> Vec<Case> {
        let mut out = Vec::new();
        if v.episodes.len() > 1 {
            out.push(Case { episodes: v.episodes[..1].to_vec(), ..v.clone() });
        }
        if let Some(ep) = v.episodes.first() {
            if ep.rewards.len() > 1 {
                let mut c = v.clone();
                c.episodes[0].rewards.truncate(ep.rewards.len() / 2);
                out.push(c);
            }
        }
        if v.n > 1 {
            out.push(Case { n: 1, ..v.clone() });
        }
        out
    }
}

/// Feed the case's episodes through an N-step writer; steps encode
/// their (episode, step) coordinates in obs/next_obs so boundary leaks
/// are detectable from the recorded items alone.
fn run_case(case: &Case) -> Vec<Transition> {
    let (mut w, rec) = recording_writer(ItemKind::NStep { n: case.n, gamma: case.gamma });
    for (e, ep) in case.episodes.iter().enumerate() {
        let last = ep.rewards.len() - 1;
        for (j, &r) in ep.rewards.iter().enumerate() {
            w.append(WriterStep {
                obs: vec![e as f32, j as f32],
                action: vec![j as f32],
                next_obs: vec![e as f32, j as f32 + 1.0],
                reward: r,
                done: j == last && ep.terminal,
                truncated: j == last && !ep.terminal,
            });
        }
    }
    let items = rec.items.lock().unwrap().clone();
    items
}

/// The writer's fold, recomputed independently (same f32 op order).
fn expected_reward(rewards: &[f32], start: usize, end: usize, gamma: f32) -> f32 {
    let mut sum = 0.0f32;
    let mut g = 1.0f32;
    for r in &rewards[start..=end] {
        sum += g * r;
        g *= gamma;
    }
    sum
}

#[test]
fn prop_nstep_reward_is_discounted_fold_of_one_step_rewards() {
    check("nstep-fold", 0xF01D, 120, &CaseGen, |case| {
        let items = run_case(case);
        // Every step of every episode starts exactly one item, in order.
        let total: usize = case.episodes.iter().map(|e| e.rewards.len()).sum();
        if items.len() != total {
            return Err(format!("{} items for {total} steps", items.len()));
        }
        let mut it = items.iter();
        for (e, ep) in case.episodes.iter().enumerate() {
            let len = ep.rewards.len();
            for j in 0..len {
                let item = it.next().expect("count checked above");
                if item.obs[0] != e as f32 || item.obs[1] != j as f32 {
                    return Err(format!(
                        "item order broken: expected ep {e} step {j}, got obs {:?}",
                        item.obs
                    ));
                }
                // Window end: full n steps, clipped at the boundary.
                let end = (j + case.n - 1).min(len - 1);
                let want = expected_reward(&ep.rewards, j, end, case.gamma);
                let got = item.reward;
                if (want - got).abs() > 1e-5 * want.abs().max(1.0) {
                    return Err(format!(
                        "ep {e} item {j}: folded reward {got}, want {want} \
                         (n={}, gamma={})",
                        case.n, case.gamma
                    ));
                }
                // Boundary integrity: the item's bootstrap observation
                // stays inside its own episode and lands exactly one
                // step past the window.
                if item.next_obs[0] != e as f32 {
                    return Err(format!(
                        "ep {e} item {j} leaks into episode {}",
                        item.next_obs[0]
                    ));
                }
                if item.next_obs[1] != (end + 1) as f32 {
                    return Err(format!(
                        "ep {e} item {j}: window end {} but next_obs points at {}",
                        end, item.next_obs[1]
                    ));
                }
                // Terminal flag: only window-reaches-terminal items of a
                // truly terminal episode; truncation bootstraps through.
                let want_done = ep.terminal && end == len - 1;
                if item.done != want_done {
                    return Err(format!(
                        "ep {e} item {j}: done={}, want {want_done}",
                        item.done
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn one_step_writer_matches_legacy_direct_inserts() {
    // The 1-step service path must be byte-for-byte the old
    // `buffer.insert_from(actor, transition)` actor loop.
    let (mut w, rec) = recording_writer(ItemKind::OneStep);
    let direct = RecordingBuffer::new();
    let mut rng = Rng::new(11);
    for i in 0..100usize {
        let done = rng.chance(0.1);
        let truncated = !done && rng.chance(0.05);
        let step = WriterStep {
            obs: vec![i as f32, rng.f32()],
            action: vec![rng.f32()],
            next_obs: vec![i as f32 + 1.0, rng.f32()],
            reward: rng.range_f32(-1.0, 1.0),
            done,
            truncated,
        };
        // Legacy loop: bootstrap-through-truncation applied inline.
        direct.insert(&Transition {
            obs: step.obs.clone(),
            action: step.action.clone(),
            next_obs: step.next_obs.clone(),
            reward: step.reward,
            done: step.done && !step.truncated,
        });
        w.append(step);
    }
    let service_items = rec.items.lock().unwrap();
    let direct_items = direct.items.lock().unwrap();
    assert_eq!(service_items.len(), direct_items.len());
    for (a, b) in service_items.iter().zip(direct_items.iter()) {
        assert_eq!(a, b);
    }
}

#[test]
fn sequence_windows_never_span_episodes() {
    let (mut w, rec) = recording_writer(ItemKind::Sequence { len: 3 });
    // Episodes of length 4 and 5: one full window each, partials dropped.
    for (e, len) in [(0usize, 4usize), (1, 5)] {
        for j in 0..len {
            w.append(WriterStep {
                obs: vec![e as f32, j as f32],
                action: vec![0.0],
                next_obs: vec![e as f32, j as f32 + 1.0],
                reward: 1.0,
                done: j == len - 1,
                truncated: false,
            });
        }
    }
    let items = rec.items.lock().unwrap();
    assert_eq!(items.len(), 2);
    assert_eq!(w.dropped_partial(), 2);
    for item in items.iter() {
        // Flattened obs holds 3 steps × [episode, step]: all three
        // episode coordinates must agree.
        assert_eq!(item.obs.len(), 6);
        assert_eq!(item.obs[0], item.obs[2]);
        assert_eq!(item.obs[2], item.obs[4]);
        assert_eq!(item.reward, 3.0);
    }
}

// ---------------------------------------------------------------------
// Per-table priority-exponent grammar (`name=kind[@cap,alpha=..,beta=..]`)
// ---------------------------------------------------------------------

#[test]
fn table_spec_exponent_grammar_accepts_valid_entries() {
    let cases = [
        ("t=1step@alpha=0.7", Some(0.7), None, None),
        ("t=1step@beta=0.25", None, Some(0.25), None),
        ("t=1step@alpha=1,beta=0", Some(1.0), Some(0.0), None),
        ("t=nstep:3@4096,alpha=0.5", Some(0.5), None, Some(4096)),
        ("t=seq:4@alpha=0.9,beta=0.4,128", Some(0.9), Some(0.4), Some(128)),
        ("t=1step@ alpha = 0.5 , beta = 0.5 ", Some(0.5), Some(0.5), None),
    ];
    for (spec, alpha, beta, capacity) in cases {
        let s = TableSpec::parse(spec, 0.99).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(s.alpha, alpha, "{spec}");
        assert_eq!(s.beta, beta, "{spec}");
        assert_eq!(s.capacity, capacity, "{spec}");
    }
}

#[test]
fn table_spec_exponent_grammar_rejects_malformed_entries() {
    let bad = [
        "t=1step@alpha=",          // missing value
        "t=1step@alpha=x",         // non-numeric
        "t=1step@gamma=0.5",       // unknown key
        "t=1step@alpha=0.5,alpha=0.6", // duplicate exponent
        "t=1step@64,128",          // duplicate capacity
        "t=1step@",                // empty option
        "t=1step@,",               // empty options
        "t=1step@alpha",           // bare non-numeric option
    ];
    for spec in bad {
        assert!(TableSpec::parse(spec, 0.99).is_err(), "`{spec}` must be rejected");
    }
}

#[test]
fn table_spec_exponent_grammar_rejects_out_of_range_values() {
    let bad = [
        "t=1step@alpha=1.5",
        "t=1step@alpha=-0.1",
        "t=1step@beta=2",
        "t=1step@beta=-1e9",
        "t=1step@alpha=nan",
        "t=1step@beta=inf",
    ];
    for spec in bad {
        let err = TableSpec::parse(spec, 0.99).unwrap_err().to_string();
        assert!(
            err.contains("[0, 1]") || err.contains("bad"),
            "`{spec}` rejected without naming the range: {err}"
        );
    }
}

// ---------------------------------------------------------------------
// Per-table rate-limiter grammar (`name=kind[@...,limit=spec]`)
// ---------------------------------------------------------------------

#[test]
fn table_spec_limit_grammar_accepts_valid_entries() {
    use pal_rl::service::RateLimitSpec;
    let cases = [
        ("t=1step@limit=legacy", Some(RateLimitSpec::Legacy)),
        ("t=1step@limit=unlimited", Some(RateLimitSpec::Unlimited)),
        ("t=1step@limit=none", Some(RateLimitSpec::Unlimited)),
        ("t=1step@limit=0.5", Some(RateLimitSpec::SamplesPerInsert(0.5))),
        ("t=1step@limit=8", Some(RateLimitSpec::SamplesPerInsert(8.0))),
        ("t=nstep:3@4096,alpha=0.5,limit=2", Some(RateLimitSpec::SamplesPerInsert(2.0))),
        ("t=1step@alpha=0.7,beta=0.4", None),
    ];
    for (spec, limit) in cases {
        let s = TableSpec::parse(spec, 0.99).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(s.limit, limit, "{spec}");
    }
    // The limit option composes with everything else in one entry, in
    // any position, and survives the list split.
    let specs = TableSpec::parse_list(
        "hot=1step@100,limit=1.5,alpha=0.9, cold=seq:4@limit=unlimited,beta=0.2",
        0.99,
    )
    .unwrap();
    assert_eq!(specs.len(), 2);
    assert_eq!(specs[0].limit, Some(RateLimitSpec::SamplesPerInsert(1.5)));
    assert_eq!(specs[0].capacity, Some(100));
    assert_eq!(specs[0].alpha, Some(0.9));
    assert_eq!(specs[1].limit, Some(RateLimitSpec::Unlimited));
    assert_eq!(specs[1].beta, Some(0.2));
}

#[test]
fn table_spec_limit_grammar_rejects_malformed_entries() {
    let bad = [
        "t=1step@limit=",          // missing value
        "t=1step@limit=fast",      // not a limiter spec
        "t=1step@limit=-1",        // sigma must be positive
        "t=1step@limit=0",         // sigma must be positive
        "t=1step@limit=nan",       // non-finite sigma
        "t=1step@limit=1,limit=2", // duplicate
    ];
    for spec in bad {
        assert!(TableSpec::parse(spec, 0.99).is_err(), "`{spec}` must be rejected");
    }
    // `limit` is a reserved option key: it cannot start an entry.
    assert!(TableSpec::parse_list("limit=2", 0.99).is_err());
    assert!(TableSpec::parse_list("limit=2,t=1step", 0.99).is_err());
}

#[test]
fn prop_in_range_exponents_always_parse_and_roundtrip() {
    // Any α/β pair on a [0, 1] lattice must parse, land in the spec
    // unchanged, and survive a format->parse round trip.
    let gen = Pair(UsizeIn { lo: 0, hi: 100 }, UsizeIn { lo: 0, hi: 100 });
    check("tablespec-exponents", 0xA1FA, 200, &gen, |&(a, b)| {
        let (alpha, beta) = (a as f32 / 100.0, b as f32 / 100.0);
        let spec = format!("t=1step@alpha={alpha},beta={beta}");
        let parsed = TableSpec::parse(&spec, 0.99).map_err(|e| e.to_string())?;
        if parsed.alpha != Some(alpha) || parsed.beta != Some(beta) {
            return Err(format!(
                "{spec} parsed to alpha={:?} beta={:?}",
                parsed.alpha, parsed.beta
            ));
        }
        let relisted = TableSpec::parse_list(&spec, 0.99).map_err(|e| e.to_string())?;
        if relisted.len() != 1 || relisted[0] != parsed {
            return Err(format!("parse_list split `{spec}` into {relisted:?}"));
        }
        Ok(())
    });
}
