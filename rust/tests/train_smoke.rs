//! End-to-end integration: full train() runs — actors + learners +
//! parameter server + prioritized buffer + PJRT graphs — on short
//! budgets, for every algorithm family and several buffer kinds.
//!
//! Requires `make artifacts`; each test skips gracefully when missing.

use pal_rl::coordinator::{train, BufferKind, TrainConfig};

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

fn base(algo: &str, env: &str) -> TrainConfig {
    let mut cfg = TrainConfig::new(algo, env);
    cfg.artifact_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.total_env_steps = 600;
    cfg.warmup_steps = 100;
    cfg.buffer_capacity = 4_096;
    cfg.exploration.eps_decay_steps = 400;
    cfg.seed = 7;
    cfg
}

fn run_and_check(cfg: TrainConfig) {
    let r = train(&cfg).expect("training failed");
    assert!(r.env_steps >= cfg.total_env_steps, "{} < {}", r.env_steps, cfg.total_env_steps);
    assert!(r.learn_steps > 0, "no learn steps happened");
    assert!(r.episodes > 0, "no episodes finished");
    assert!(r.final_mean_return.is_finite());
    // Ratio pacing: learners must not exceed the configured ratio.
    let max_learn = (r.env_steps as f64 / cfg.update_interval).ceil() + cfg.learners as f64;
    assert!(
        (r.learn_steps as f64) <= max_learn,
        "pacing violated: {} learn steps vs {} env steps (ratio {})",
        r.learn_steps,
        r.env_steps,
        cfg.update_interval
    );
}

#[test]
fn dqn_cartpole_single_worker() {
    if !have_artifacts() {
        return;
    }
    run_and_check(base("dqn", "CartPole-v1"));
}

#[test]
fn dqn_cartpole_parallel_workers() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("dqn", "CartPole-v1");
    cfg.actors = 2;
    cfg.learners = 2;
    cfg.update_interval = 2.0;
    run_and_check(cfg);
}

#[test]
fn ddqn_cartpole_runs() {
    if !have_artifacts() {
        return;
    }
    run_and_check(base("ddqn", "CartPole-v1"));
}

#[test]
fn ddpg_pendulum_runs() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("ddpg", "Pendulum-v1");
    cfg.update_interval = 2.0; // learn graphs are pricier; keep test fast
    run_and_check(cfg);
}

#[test]
fn td3_pendulum_runs_with_policy_delay() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("td3", "Pendulum-v1");
    cfg.update_interval = 2.0;
    run_and_check(cfg);
}

#[test]
fn sac_pendulum_runs() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("sac", "Pendulum-v1");
    cfg.update_interval = 2.0;
    run_and_check(cfg);
}

#[test]
fn all_buffer_kinds_train() {
    if !have_artifacts() {
        return;
    }
    for kind in [
        BufferKind::PalKary,
        BufferKind::GlobalLock,
        BufferKind::Uniform,
        BufferKind::EmulatedPython,
        BufferKind::EmulatedBinding,
    ] {
        let mut cfg = base("dqn", "CartPole-v1");
        cfg.buffer = kind;
        cfg.total_env_steps = 300;
        cfg.warmup_steps = 64;
        run_and_check(cfg);
    }
}

#[test]
fn early_stop_on_reward_target() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("dqn", "CartPole-v1");
    // Trivially reachable target: any mean return over 10 episodes > 1.
    cfg.stop_at_reward = Some(1.0);
    cfg.total_env_steps = 50_000; // would take long without early stop
    let r = train(&cfg).unwrap();
    assert!(r.reached_target);
    assert!(r.env_steps < 50_000, "early stop did not trigger");
}
