//! Integration smoke: jax-lowered HLO text loads, compiles and executes
//! with correct numerics through the runtime. Requires `make artifacts`
//! (or the reference gen_hlo.py) to have produced the smoke artifact.
use pal_rl::runtime::Runtime;

#[test]
fn load_and_execute_smoke_hlo() {
    let path =
        std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/smoke.hlo.txt"));
    if !path.exists() {
        eprintln!("skipping: smoke artifact missing (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(path).unwrap();
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
    let result = exe.execute::<xla::Literal>(&[x, y]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let out = result.to_tuple1().unwrap();
    assert_eq!(out.to_vec::<f32>().unwrap(), vec![5f32, 5., 9., 9.]);
}
