//! Seeded multi-threaded drill for the lazy-writing "benign race"
//! (paper §IV-D2): an insert zeroes the slot's priority, copies the row
//! outside the locks, then restores a positive priority — so a
//! concurrent sampler must NEVER surface a half-written row. Rows are
//! self-describing (every obs component equals the reward, and every
//! next_obs component is its negation), so a torn copy that mixes two
//! writes is detectable from the sampled batch alone.
//!
//! The drill runs across fan-outs 16/64/256 (one group per cache line,
//! several lines per group) because the chunked descent scan and the
//! min-plane skip treat group boundaries differently at each.
//!
//! A second soak hammers inserts + priority updates through eviction
//! churn WITHOUT ever calling `rebuild_tree`, asserting the summed-area
//! invariant drift stays bounded — the lazy zero/restore pairs and the
//! min-plane skip must not leak error into interior nodes.

use pal_rl::replay::{
    PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition,
};
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

const OBS_DIM: usize = 8;
const ACT_DIM: usize = 2;
const BATCH: usize = 32;

/// A row whose payload is recognizable: obs = [v; 8], next_obs = [-v; 8],
/// reward = v. Any interleaving of two different writes breaks the
/// equalities.
fn marked(v: f32) -> Transition {
    Transition {
        obs: vec![v; OBS_DIM],
        action: vec![0.1; ACT_DIM],
        next_obs: vec![-v; OBS_DIM],
        reward: v,
        done: false,
    }
}

/// Assert every sampled row is internally consistent and was drawn with
/// a strictly positive priority. Returns the number of rows checked.
fn check_batch(out: &SampleBatch, fanout: usize) -> usize {
    for (j, &idx) in out.indices.iter().enumerate() {
        let p = out.priorities[j];
        assert!(
            p > 0.0,
            "fanout {fanout}: sampled index {idx} with non-positive priority {p} \
             (zero-priority guard breached)"
        );
        let v = out.reward[j];
        let obs = &out.obs[j * OBS_DIM..(j + 1) * OBS_DIM];
        let next = &out.next_obs[j * OBS_DIM..(j + 1) * OBS_DIM];
        for d in 0..OBS_DIM {
            assert!(
                obs[d] == v && next[d] == -v,
                "fanout {fanout}: torn row at index {idx}: reward {v}, \
                 obs[{d}] = {}, next_obs[{d}] = {}",
                obs[d],
                next[d],
            );
        }
    }
    out.indices.len()
}

#[test]
fn lazy_race_never_surfaces_half_written_rows() {
    const INSERTERS: usize = 4;
    const SAMPLERS: usize = 2;
    const INSERTS_PER_THREAD: usize = 4_000;
    const PREFILL: usize = 2_000;
    // Capacity exceeds everything ever inserted, so slots are never
    // evicted mid-drill and a sampled row must exactly match one write.
    const CAPACITY: usize = 40_000;

    for &fanout in &[16usize, 64, 256] {
        let buf = Arc::new(PrioritizedReplay::new(PrioritizedConfig {
            capacity: CAPACITY,
            obs_dim: OBS_DIM,
            act_dim: ACT_DIM,
            fanout,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        }));
        for i in 0..PREFILL {
            buf.insert(&marked(i as f32));
        }
        let finished = AtomicUsize::new(0);
        let checked = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for tid in 0..INSERTERS {
                let buf = Arc::clone(&buf);
                let finished = &finished;
                s.spawn(move || {
                    // v = tid * 1e6 + i stays under 2^24, so every value
                    // (and its negation) is exact in f32.
                    for i in 0..INSERTS_PER_THREAD {
                        buf.insert_from(tid, &marked((tid * 1_000_000 + i) as f32));
                    }
                    finished.fetch_add(1, Ordering::Relaxed);
                });
            }
            for tid in 0..SAMPLERS {
                let buf = Arc::clone(&buf);
                let finished = &finished;
                let checked = &checked;
                s.spawn(move || {
                    let mut rng = Rng::new(7 + tid as u64);
                    let mut out = SampleBatch::default();
                    // Keep checking until every inserter has retired, so
                    // samplers overlap the entire write storm.
                    while finished.load(Ordering::Relaxed) < INSERTERS {
                        if buf.sample(BATCH, &mut rng, &mut out) {
                            checked.fetch_add(check_batch(&out, fanout), Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0 + 0.01).collect();
                            buf.update_priorities(&idx, &tds);
                        } else {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        assert!(
            checked.load(Ordering::Relaxed) > 0,
            "fanout {fanout}: samplers never drew a batch"
        );
        // Post-drill: the tree still satisfies its summed-area invariant.
        assert!(
            buf.tree().invariant_error() < 1e-2,
            "fanout {fanout}: invariant drift {} after drill",
            buf.tree().invariant_error()
        );
    }
}

#[test]
fn invariant_bounded_over_long_soak_without_rebuild() {
    let buf = PrioritizedReplay::new(PrioritizedConfig {
        capacity: 8_192,
        obs_dim: OBS_DIM,
        act_dim: ACT_DIM,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    });
    for i in 0..8_192 {
        buf.insert(&marked(i as f32));
    }
    let mut rng = Rng::new(42);
    for step in 0..50_000usize {
        let idx: Vec<usize> = (0..BATCH).map(|_| rng.below_usize(8_192)).collect();
        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
        buf.update_priorities(&idx, &tds);
        if step % 8 == 0 {
            // Eviction churn: overwrite a wrapped slot through the lazy
            // zero/copy/restore path.
            buf.insert(&marked((step % 1_000_000) as f32));
        }
        if step % 10_000 == 0 {
            assert!(
                buf.tree().invariant_error() < 1e-2,
                "invariant drift {} at step {step} (no rebuild ever issued)",
                buf.tree().invariant_error()
            );
        }
    }
    assert!(
        buf.tree().invariant_error() < 1e-2,
        "invariant drift {} after 50k-step soak without rebuild",
        buf.tree().invariant_error()
    );
}
