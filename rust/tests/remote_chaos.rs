//! Chaos soaks for the remote replay front-end, driven through the
//! seeded fault-injecting [`ChaosProxy`]: killed connections, full
//! outages (blackhole + spill), a silent partition against the mesh
//! health ladder, a server restart from checkpoint, and probabilistic
//! delay/shred/reset streams. Every test asserts the fault-tolerance
//! contract end to end — exactly-once appends across reconnects,
//! bounded spill with accounted drops, bounded per-batch latency under
//! partition, and final state byte-identical to a fault-free
//! in-process twin.

mod common;

use common::{start_server, stop_server};
use pal_rl::remote::{
    BackoffPolicy, ChaosConfig, ChaosProxy, ConnectionPolicy, Endpoint, HealthState, MeshSampler,
    RemoteClient, RemoteSampler, RemoteWriter, ReplayServer,
};
use pal_rl::replay::{SampleBatch, UniformReplay};
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimiter, ReplayService, SampleOutcome,
    ServiceState, Table, WriterStep,
};
use pal_rl::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32, -(i as f32)],
        action: vec![0.25],
        next_obs: vec![i as f32 + 1.0, -(i as f32)],
        reward: (i % 5) as f32,
        done: i % 17 == 16,
        truncated: false,
    }
}

/// One unlimited-rate uniform `replay` table (obs dim 2, act dim 1) —
/// built twice per test so the served service and its in-process twin
/// start identical.
fn service_cap(capacity: usize) -> Arc<ReplayService> {
    Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::new(UniformReplay::new(capacity, 2, 1)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap(),
    )
}

/// Short supervised-reconnect policy: generous per-RPC timeout, but a
/// 10 s overall deadline so a broken test fails instead of hanging.
fn policy() -> ConnectionPolicy {
    ConnectionPolicy {
        rpc_timeout: Duration::from_secs(5),
        backoff: BackoffPolicy::default().with_deadline(Duration::from_secs(10)),
    }
}

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pal_{}_{}", tag, std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Byte-compare the server's checkpoint against an in-process twin fed
/// the given steps by one local writer (plus bulk drop accounting).
fn assert_state_matches_twin(
    server_path: &std::path::Path,
    actor_id: usize,
    steps: impl Iterator<Item = usize>,
    dropped: usize,
) {
    let remote_bytes = RemoteClient::connect(server_path).unwrap().checkpoint_bytes().unwrap();
    let twin = service_cap(256);
    let mut tw = twin.writer(actor_id);
    for i in steps {
        tw.append(step(i));
    }
    if dropped > 0 {
        for t in twin.tables() {
            t.add_steps_dropped(dropped);
        }
    }
    let twin_bytes = ServiceState::capture(&twin).unwrap().encode();
    assert_eq!(remote_bytes, twin_bytes, "served state must be byte-identical to the twin");
}

#[test]
fn writer_survives_killed_connections_exactly_once_and_byte_identical() {
    let served = service_cap(256);
    let (server_path, handle) = start_server(Arc::clone(&served));
    let dir = test_dir("chaos_kill");
    let proxy_sock = dir.join("proxy.sock");
    let mut proxy = ChaosProxy::start(&server_path, &proxy_sock, ChaosConfig::default()).unwrap();

    let mut w = RemoteWriter::connect_with(&proxy_sock, 0, policy()).unwrap().with_batch(4);
    for i in 0..20 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);

    // Hard-drop the live connection mid-stream; the next appends must
    // heal onto a resumed session with no loss and no duplication.
    assert!(proxy.kill_connections() >= 1, "the writer connection must have been live");
    for i in 20..40 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);
    assert!(w.reconnects() >= 1, "the kill must have forced a redial");
    assert_eq!(w.steps_dropped(), 0);

    let t = served.table("replay").unwrap();
    assert_eq!(t.len(), 40);
    assert_eq!(t.stats_snapshot().inserts, 40, "a resumed session must dedupe, not re-insert");
    assert_state_matches_twin(&server_path, 0, 0..40, 0);

    drop(w);
    proxy.stop();
    stop_server(&server_path, handle);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn writer_spill_overflow_drops_oldest_and_accounts_the_drops() {
    let served = service_cap(256);
    let (server_path, handle) = start_server(Arc::clone(&served));
    let dir = test_dir("chaos_spill");
    let proxy_sock = dir.join("proxy.sock");
    let mut proxy = ChaosProxy::start(&server_path, &proxy_sock, ChaosConfig::default()).unwrap();

    let w = RemoteWriter::connect_with(&proxy_sock, 1, policy()).unwrap();
    let mut w = w.with_batch(4).with_spill_cap(8);

    // Full outage: kill the live connection and blackhole redials.
    proxy.set_blackhole(true);
    proxy.kill_connections();
    for i in 0..40 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.pending_len(), 8, "spill must stay bounded at the cap");
    assert_eq!(w.steps_dropped(), 32, "overflow drops are counted, oldest-first");

    // Outage over: the bounded spill window lands, with the drops
    // reported to the server's accounting.
    proxy.set_blackhole(false);
    assert_eq!(w.flush().unwrap(), 0);
    assert!(w.reconnects() >= 1);

    let t = served.table("replay").unwrap();
    assert_eq!(t.len(), 8, "only the surviving spill window lands");
    assert_eq!(t.stats_snapshot().inserts, 8);
    assert_eq!(t.stats_snapshot().steps_dropped, 32, "the server books the writer's drops");
    // Survivors are the in-flight chunk (pinned at the outage) plus
    // the newest steps — byte-identical to a twin fed exactly those.
    assert_state_matches_twin(&server_path, 1, (0..4usize).chain(36..40), 32);

    drop(w);
    proxy.stop();
    stop_server(&server_path, handle);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sampler_prefetch_rearms_across_killed_connections() {
    let served = service_cap(256);
    let (server_path, handle) = start_server(Arc::clone(&served));

    // Fill the table directly over the server socket.
    let mut w = RemoteWriter::connect(&server_path, 0).unwrap();
    for i in 0..64 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);

    let dir = test_dir("chaos_sampler");
    let proxy_sock = dir.join("proxy.sock");
    let mut proxy = ChaosProxy::start(&server_path, &proxy_sock, ChaosConfig::default()).unwrap();
    let smp = RemoteSampler::connect_default_with(&proxy_sock, 7, policy()).unwrap();
    let mut smp = smp.with_prefetch(true);
    let mut rng = Rng::new(0); // ignored by the remote sampler
    let mut out = SampleBatch::default();
    for _ in 0..3 {
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
        assert_eq!(out.len(), 8);
        assert!(out.priorities.iter().all(|&p| p > 0.0));
    }

    // Kill the connection with a prefetch in flight: the sampler must
    // reconnect, re-arm its pipeline, and keep granting valid batches.
    assert!(proxy.kill_connections() >= 1, "the sampler connection must have been live");
    for _ in 0..3 {
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
        assert_eq!(out.len(), 8);
        assert!(out.priorities.iter().all(|&p| p > 0.0));
    }
    assert!(smp.reconnects() >= 1, "the kill must have forced a redial");

    smp.finish().unwrap();
    drop(smp);
    drop(w);
    proxy.stop();
    stop_server(&server_path, handle);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mesh_sampler_survives_silent_partition_with_bounded_latency() {
    // Two single-table servers; the mesh reaches server 1 (the victim)
    // through a chaos proxy that can flip into a silent partition:
    // connections stay open, writes succeed, nothing ever arrives —
    // the failure only the RPC read timeout can detect.
    let served0 = service_cap(256);
    let served1 = service_cap(256);
    let (path0, h0) = start_server(Arc::clone(&served0));
    let (path1, h1) = start_server(Arc::clone(&served1));
    let dir = test_dir("chaos_partition");
    let proxy_sock = dir.join("proxy.sock");
    let mut proxy = ChaosProxy::start(&path1, &proxy_sock, ChaosConfig::default()).unwrap();

    // Fill both servers directly (the proxy only fronts the sampler).
    for (actor, path) in [(0u64, &path0), (1u64, &path1)] {
        let mut w = RemoteWriter::connect(path, actor).unwrap();
        for i in 0..64 {
            w.append(step(i)).unwrap();
        }
        assert_eq!(w.flush().unwrap(), 0);
    }

    // Short per-RPC timeout: under a silent partition it is the ONLY
    // failure signal, and the latency bound every draw must honour.
    let rpc_timeout = Duration::from_millis(300);
    let mesh_policy = ConnectionPolicy {
        rpc_timeout,
        backoff: BackoffPolicy::default().with_deadline(Duration::from_secs(2)),
    };
    let eps = [Endpoint::Uds(path0.clone()), Endpoint::Uds(proxy_sock.clone())];
    let mut smp = MeshSampler::connect_default(&eps, 0xC4A0_11, mesh_policy).unwrap();
    let stride = smp.stride();
    let mut rng = Rng::new(0); // ignored by the mesh sampler
    let mut out = SampleBatch::default();

    // Healthy warm-up: both servers advertise mass and answer draws.
    for _ in 0..4 {
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
    }
    assert_eq!(smp.health(1), HealthState::Up);

    // Silent partition against the victim. Every draw must still grant
    // a full batch (from the survivor) within a small multiple of the
    // RPC timeout — one timed-out mass probe plus one timed-out redial
    // hello, never the blocking backoff loop — while the victim walks
    // the health ladder instead of stalling the learner.
    proxy.set_stall(true);
    let latency_bound = 8 * rpc_timeout;
    for _ in 0..6 {
        let t = Instant::now();
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
        let dt = t.elapsed();
        assert!(
            dt < latency_bound,
            "a partitioned server must not stall the learner: draw took {dt:?} \
             (bound {latency_bound:?})"
        );
        assert_eq!(out.len(), 8);
        assert!(
            out.indices.iter().all(|&i| i / stride == 0),
            "every partition-phase batch must come from the survivor"
        );
    }
    assert_eq!(smp.health(1), HealthState::Down, "the victim must reach Down, not stall");
    let mid = smp.counters();
    assert!(mid.downs >= 1, "the Up→Down transition must be counted");
    assert!(mid.degraded_draws >= 1, "draws with a dead member are degraded draws");

    // Partition heals: the seeded recovery probe redials, the victim
    // climbs back to Up, and its mass re-enters the level-1 draw.
    proxy.set_stall(false);
    let mut healed = false;
    for _ in 0..800 {
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
        if smp.health(1) == HealthState::Up {
            healed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(healed, "the victim must rejoin once the partition clears");
    assert!(smp.counters().rejoins >= 1, "the rejoin must be counted");
    let mut victim_sampled = false;
    for _ in 0..200 {
        assert_eq!(smp.try_sample(8, &mut rng, &mut out).unwrap(), SampleOutcome::Sampled);
        if out.indices.iter().any(|&i| i / stride == 1) {
            victim_sampled = true;
            break;
        }
    }
    assert!(victim_sampled, "a rejoined server must serve draws again");

    drop(smp);
    proxy.stop();
    stop_server(&path0, h0);
    stop_server(&path1, h1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn server_restart_resumes_writers_from_checkpoint_byte_identical() {
    let dir = test_dir("chaos_restart");
    let sock = dir.join("server.sock");

    // First life.
    let served1 = service_cap(256);
    let server1 = ReplayServer::bind(Arc::clone(&served1), &sock, 42)
        .unwrap()
        .with_drain_deadline(Duration::from_millis(500));
    let h1 = std::thread::spawn(move || server1.serve());

    let mut w = RemoteWriter::connect_with(&sock, 2, policy()).unwrap().with_batch(8);
    for i in 0..30 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);
    let ck = RemoteClient::connect(&sock).unwrap().checkpoint_bytes().unwrap();

    // Take the server down; its socket goes away with it.
    RemoteClient::connect(&sock).unwrap().shutdown().unwrap();
    h1.join().unwrap().unwrap();
    assert!(RemoteClient::connect(&sock).is_err(), "nothing must listen between server lives");

    // Outage appends spill client-side (well under the default cap).
    for i in 30..40 {
        w.append(step(i)).unwrap();
    }

    // Second life: fresh process state, tables restored from the
    // checkpoint, same socket path.
    let served2 = service_cap(256);
    served2.restore(&ServiceState::decode(&ck).unwrap()).unwrap();
    let server2 = ReplayServer::bind(Arc::clone(&served2), &sock, 42)
        .unwrap()
        .with_drain_deadline(Duration::from_millis(500));
    let h2 = std::thread::spawn(move || server2.serve());

    // The restarted server cannot resume the old session (new boot
    // nonce): the writer must bind a fresh one and re-ship everything
    // unacked — exactly once on top of the restored state.
    assert_eq!(w.flush().unwrap(), 0, "flush must heal onto the restarted server");
    assert!(w.reconnects() >= 1);
    assert_eq!(w.steps_dropped(), 0);

    let t = served2.table("replay").unwrap();
    assert_eq!(t.len(), 40);
    assert_eq!(t.stats_snapshot().inserts, 40);
    assert_state_matches_twin(&sock, 2, 0..40, 0);

    drop(w);
    RemoteClient::connect(&sock).unwrap().shutdown().unwrap();
    h2.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn seeded_chaos_faults_never_lose_or_duplicate_steps() {
    let served = service_cap(256);
    let (server_path, handle) = start_server(Arc::clone(&served));
    let dir = test_dir("chaos_faulty");
    let cfg = ChaosConfig {
        seed: 0x5EED_CA05,
        delay_chance: 0.05,
        max_delay: Duration::from_millis(2),
        shred_chance: 0.20,
        reset_chance: 0.02,
        max_resets: 3,
    };
    let proxy_sock = dir.join("proxy.sock");
    let mut proxy = ChaosProxy::start(&server_path, &proxy_sock, cfg).unwrap();

    // Connect under fault injection: the initial hello may eat a reset,
    // so dial in a short retry loop like any supervised client would.
    let mut writer = None;
    for _ in 0..10 {
        match RemoteWriter::connect_with(&proxy_sock, 3, policy()) {
            Ok(h) => {
                writer = Some(h);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let mut w = writer.expect("writer connect kept failing under chaos").with_batch(8);

    for i in 0..200 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);
    assert_eq!(w.steps_dropped(), 0);

    let t = served.table("replay").unwrap();
    assert_eq!(t.stats_snapshot().inserts, 200, "faults must never lose or duplicate a step");
    assert_eq!(t.len(), 200);
    // Delays, shreds, and resets left the stream byte-equivalent to a
    // fault-free run.
    assert_state_matches_twin(&server_path, 3, 0..200, 0);

    drop(w);
    proxy.stop();
    stop_server(&server_path, handle);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tcp_chaos_faults_never_lose_or_duplicate_steps() {
    // The seeded fault drill again, but every hop — server bind, chaos
    // proxy listen/dial, writer, and control client — runs over TCP,
    // proving the transport abstraction changes nothing about the
    // fault-tolerance contract.
    let served = service_cap(256);
    let bind = Endpoint::tcp("127.0.0.1:0").unwrap();
    let server = ReplayServer::bind_endpoint(Arc::clone(&served), &bind, 42)
        .unwrap()
        .with_drain_deadline(Duration::from_millis(500));
    let server_ep = server.endpoint();
    let handle = std::thread::spawn(move || server.serve());

    let cfg = ChaosConfig {
        seed: 0x7C9_5EED,
        delay_chance: 0.05,
        max_delay: Duration::from_millis(2),
        shred_chance: 0.20,
        reset_chance: 0.02,
        max_resets: 3,
    };
    let listen = Endpoint::tcp("127.0.0.1:0").unwrap();
    let mut proxy = ChaosProxy::start_endpoints(&server_ep, &listen, cfg).unwrap();
    let proxy_ep = proxy.listen_endpoint().clone();

    // As in the UDS drill, the initial hello may eat a seeded reset.
    let mut writer = None;
    for _ in 0..10 {
        match RemoteWriter::connect_endpoint_with(&proxy_ep, 4, policy()) {
            Ok(h) => {
                writer = Some(h);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let mut w = writer.expect("writer connect kept failing under chaos").with_batch(8);

    for i in 0..120 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);

    // A hard kill through the TCP proxy must heal exactly like the UDS
    // one: resumed session, no loss, no duplication.
    assert!(proxy.kill_connections() >= 1, "the writer connection must have been live");
    for i in 120..200 {
        w.append(step(i)).unwrap();
    }
    assert_eq!(w.flush().unwrap(), 0);
    assert!(w.reconnects() >= 1, "the kill must have forced a redial");
    assert_eq!(w.steps_dropped(), 0);

    let t = served.table("replay").unwrap();
    assert_eq!(t.stats_snapshot().inserts, 200, "TCP faults must never lose or duplicate a step");
    assert_eq!(t.len(), 200);

    // Byte-compare against a fault-free twin through the chunked
    // download (the TCP server has no socket path for the UDS helper).
    let remote_bytes = RemoteClient::connect_endpoint(&server_ep)
        .unwrap()
        .checkpoint_bytes_chunked(256)
        .unwrap();
    let twin = service_cap(256);
    let mut tw = twin.writer(4);
    for i in 0..200 {
        tw.append(step(i));
    }
    let twin_bytes = ServiceState::capture(&twin).unwrap().encode();
    assert_eq!(remote_bytes, twin_bytes, "served state must be byte-identical to the twin");

    drop(w);
    proxy.stop();
    RemoteClient::connect_endpoint(&server_ep).unwrap().shutdown().unwrap();
    handle.join().unwrap().unwrap();
}
