//! Shared scaffolding for the remote-replay integration tests: bind a
//! [`ReplayServer`] on a unique socket, serve it on a background
//! thread, wait for liveness, and end it over the `Shutdown` RPC —
//! one copy of the server lifecycle, so every suite tests the same
//! bind/drain/shutdown semantics.

use pal_rl::remote::{RemoteClient, ReplayServer};
use pal_rl::service::ReplayService;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Bind on a unique temp socket, serve on a background thread, and
/// block until the server accepts connections.
pub fn start_server(
    service: Arc<ReplayService>,
) -> (PathBuf, std::thread::JoinHandle<anyhow::Result<()>>) {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let path = std::env::temp_dir().join(format!(
        "pal_remote_test_{}_{}.sock",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let server = ReplayServer::bind(service, &path, 42).expect("bind");
    let handle = std::thread::spawn(move || server.serve());
    for _ in 0..500 {
        if std::os::unix::net::UnixStream::connect(&path).is_ok() {
            return (path, handle);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server at {} never came up", path.display());
}

/// Shutdown RPC + join; panics if the server errored.
pub fn stop_server(path: &Path, handle: std::thread::JoinHandle<anyhow::Result<()>>) {
    RemoteClient::connect(path)
        .expect("connect for shutdown")
        .shutdown()
        .expect("shutdown rpc");
    handle.join().expect("server thread").expect("serve result");
}
