//! Fig 13 — sharded prioritized replay scalability: combined
//! insert+update throughput vs shard count S and worker threads.
//!
//!     cargo bench --bench fig13_sharding -- \
//!         [--shards 1,2,4,8,16] [--threads 1,2,4,8] [--rounds N] \
//!         [--json PATH] [--test]
//!
//! `--json PATH` writes the machine-readable sweep (`BENCH_sharding.json`
//! via tools/bench_smoke.sh). The gated verdict is the DES S=4 vs S=1
//! ratio at the sweep's max thread count; the real-thread ratio is
//! recorded for the trail but not gated (1-core runners cannot show
//! parallel speedup).
//!
//! Protocol: T workers share one buffer; each round a worker inserts a
//! batch with its own affinity id (`insert_from`), draws a stratified
//! sample, and feeds the |TD| errors back through the batched priority
//! update — the learner hot loop with the act/learn compute stripped
//! away, so the buffer's locks are all that can limit scaling. Two views
//! (same convention as Figs 9/10, DESIGN.md §Substitutions):
//!
//! * real threads on this host — exercises the actual lock code; on a
//!   1-core container this measures critical-section length, not
//!   parallelism;
//! * the multicore DES projection at T cores, driven by per-op costs
//!   measured on this machine, which shows the paper-style scaling: the
//!   S=1 global tree lock saturates near 2 workers, while S ≥ 4 keeps
//!   scaling until the cores run out (≥ 2x combined throughput at 8
//!   threads).

use pal_rl::dse::CostProfile;
use pal_rl::replay::{
    PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch,
    ShardedPrioritizedReplay, Transition,
};
use pal_rl::util::bench::Table;
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

const BATCH: usize = 32;

fn tr() -> Transition {
    Transition {
        obs: vec![0.5; 8],
        action: vec![0.1; 2],
        next_obs: vec![0.6; 8],
        reward: 1.0,
        done: false,
    }
}

/// S=1 is the plain single-tree buffer (the pre-sharding code path);
/// S>1 is the sharded wrapper.
fn mk(capacity: usize, shards: usize) -> Arc<dyn ReplayBuffer> {
    let cfg = PrioritizedConfig {
        capacity,
        obs_dim: 8,
        act_dim: 2,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards,
    };
    if shards > 1 {
        Arc::new(ShardedPrioritizedReplay::new(cfg))
    } else {
        Arc::new(PrioritizedReplay::new(cfg))
    }
}

/// Combined insert+update ops/sec over T real threads.
fn run_real(buf: &Arc<dyn ReplayBuffer>, threads: usize, rounds: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tid in 0..threads {
            let buf = Arc::clone(buf);
            s.spawn(move || {
                let mut rng = Rng::new(tid as u64 + 1);
                let mut out = SampleBatch::default();
                let t = tr();
                for _ in 0..rounds {
                    for _ in 0..BATCH {
                        buf.insert_from(tid, &t);
                    }
                    if buf.sample(BATCH, &mut rng, &mut out) {
                        let idx = out.indices.clone();
                        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
                        buf.update_priorities(&idx, &tds);
                    }
                }
            });
        }
    });
    let ops = (threads * rounds * 2 * BATCH) as f64; // inserts + updated pairs
    ops / t0.elapsed().as_secs_f64()
}

/// DES combined throughput index (collect + consume cycles/sec) for the
/// buffer-dominated workload at T cores with S shards.
fn des_combined(profile: &CostProfile, shards: usize, threads: usize) -> f64 {
    let mut p = *profile;
    p.shards = shards;
    let actors = threads.div_ceil(2);
    let learners = (threads / 2).max(1);
    let r = p.joint(actors, learners, threads.max(1));
    r.collect_per_sec + r.consume_per_sec
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env()?;
    // `--test` = CI smoke: a 2x2 sweep with tiny op counts.
    let test_mode = a.flag("test");
    let default_shards: &[usize] = if test_mode { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let default_threads: &[usize] = if test_mode { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut shard_list = a.usize_list("shards", default_shards)?;
    if !shard_list.contains(&1) {
        // S=1 is the baseline every "vs S=1" column and verdict divides
        // by; always measure it.
        shard_list.insert(0, 1);
    }
    let thread_list = a.usize_list("threads", default_threads)?;
    let rounds: usize = a.parse_or("rounds", if test_mode { 20 } else { 200 })?;
    let capacity: usize = a.parse_or("capacity", if test_mode { 4_096 } else { 65_536 })?;

    println!("Fig 13 — sharded replay scalability (S x threads)\n");

    // --- Real threads on this host -----------------------------------
    println!(
        "real threads ({} host cpus), combined insert+update ops/s:",
        std::thread::available_parallelism().map_or(1, |n| n.get())
    );
    let mut t = Table::new(&["S", "threads", "ops/s", "vs S=1"]);
    let mut real: Vec<(usize, usize, f64)> = Vec::new();
    for &s in &shard_list {
        for &th in &thread_list {
            let buf = mk(capacity, s);
            for i in 0..capacity.min(10_000) {
                buf.insert_from(i, &tr());
            }
            let ops = run_real(&buf, th, rounds);
            real.push((s, th, ops));
        }
    }
    for &(s, th, ops) in &real {
        let base = real
            .iter()
            .find(|&&(s0, th0, _)| s0 == 1 && th0 == th)
            .map_or(ops, |&(_, _, o)| o);
        t.row(vec![
            s.to_string(),
            th.to_string(),
            format!("{ops:.0}"),
            format!("{:.2}x", ops / base.max(1e-9)),
        ]);
    }
    t.print();

    // --- DES projection at T cores -----------------------------------
    // Per-op costs measured live on this machine; act/env/learn set tiny
    // so the buffer locks are the only possible bottleneck, and the
    // parameter-server section kept short for the same reason.
    println!("\nmeasuring per-op costs for the DES projection ...");
    let mut profile = if test_mode {
        CostProfile::measure(500, 100, 1_000)
    } else {
        CostProfile::measure(2_000, 500, 5_000)
    };
    profile.costs.server_ns = 1_000;
    println!(
        "  insert lock {} ns | sample(64) lock {} ns | update(64) {} ns",
        profile.costs.insert_lock_ns, profile.costs.sample_lock_ns, profile.costs.update_lock_ns
    );

    println!("\nDES projection (T cores), combined collect+consume cycles/s:");
    let mut d = Table::new(&["S", "threads", "cycles/s", "vs S=1"]);
    // Per-thread S=1 baselines, computed once.
    let bases: Vec<f64> = thread_list
        .iter()
        .map(|&th| des_combined(&profile, 1, th))
        .collect();
    // (s, threads, cycles/s, vs S=1) for the JSON artifact.
    let mut des_rows: Vec<(usize, usize, f64, f64)> = Vec::new();
    for &s in &shard_list {
        for (ti, &th) in thread_list.iter().enumerate() {
            let c = if s == 1 { bases[ti] } else { des_combined(&profile, s, th) };
            let vs = c / bases[ti].max(1e-9);
            des_rows.push((s, th, c, vs));
            d.row(vec![
                s.to_string(),
                th.to_string(),
                format!("{c:.0}"),
                format!("{vs:.2}x"),
            ]);
        }
    }
    d.print();

    // --- Acceptance verdict ------------------------------------------
    let t8 = *thread_list.iter().max().unwrap_or(&8);
    let des1 = des_combined(&profile, 1, t8);
    let des4 = des_combined(&profile, 4, t8);
    let ratio = des4 / des1.max(1e-9);
    println!(
        "\nverdict (DES @ {t8} threads): S=4 vs S=1 = {ratio:.2}x — target >= 2x [{}]",
        if ratio >= 2.0 { "OK" } else { "MISS" }
    );
    // Real-thread S_max vs S=1 ratio at t8 threads — recorded in the
    // JSON trail regardless of host width, printed as a verdict only
    // when the host can actually run t8 threads in parallel.
    let r1 = real
        .iter()
        .find(|&&(s, th, _)| s == 1 && th == t8)
        .map_or(0.0, |&(_, _, o)| o);
    // Largest sharded configuration in the sweep at t8 threads.
    let best = real
        .iter()
        .filter(|&&(s, th, _)| s > 1 && th == t8)
        .max_by_key(|&&(s, _, _)| s)
        .copied();
    let real_smax = match (r1 > 0.0, best) {
        (true, Some((_, _, rs))) => Some(rs / r1),
        _ => None,
    };
    if std::thread::available_parallelism().map_or(1, |n| n.get()) >= t8 {
        if let (Some(v), Some((s, _, _))) = (real_smax, best) {
            println!("verdict (real threads @ {t8}): S={s} vs S=1 = {v:.2}x");
        }
    } else {
        println!(
            "(host has fewer than {t8} cpus: real-thread columns measure \
             critical-section length, not parallel speedup — see DES)"
        );
    }

    // --- Machine-readable output ---------------------------------------
    if let Some(path) = a.get("json") {
        let fmt_opt = |v: Option<f64>| match v {
            Some(v) => format!("{v:.3}"),
            None => "null".into(),
        };
        let mut j = String::from("{\n  \"bench\": \"fig13_sharding\",\n");
        j.push_str(&format!(
            "  \"config\": {{\"shards\": {shard_list:?}, \"threads\": {thread_list:?}, \
             \"rounds\": {rounds}, \"capacity\": {capacity}, \"batch\": {BATCH}, \
             \"smoke\": {test_mode}}},\n"
        ));
        j.push_str("  \"real_rows\": [\n");
        for (i, &(s, th, ops)) in real.iter().enumerate() {
            let base = real
                .iter()
                .find(|&&(s0, th0, _)| s0 == 1 && th0 == th)
                .map_or(ops, |&(_, _, o)| o);
            j.push_str(&format!(
                "    {{\"shards\": {s}, \"threads\": {th}, \"ops_per_sec\": {ops:.1}, \
                 \"vs_s1\": {:.3}}}{}\n",
                ops / base.max(1e-9),
                if i + 1 < real.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n  \"des_rows\": [\n");
        for (i, &(s, th, c, vs)) in des_rows.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"shards\": {s}, \"threads\": {th}, \"cycles_per_sec\": {c:.1}, \
                 \"vs_s1\": {vs:.3}}}{}\n",
                if i + 1 < des_rows.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "  ],\n  \"verdicts\": {{\"des_speedup_s4\": {ratio:.3}, \
             \"real_speedup_smax\": {}}},\n",
            fmt_opt(real_smax),
        ));
        j.push_str(
            "  \"gate\": {\"des_speedup_s4\": {\"floor\": 1.0, \"tolerance\": 0.5}}\n}\n",
        );
        std::fs::write(path, j)?;
        eprintln!("[fig13_sharding] results written to {path}");
    }
    Ok(())
}
