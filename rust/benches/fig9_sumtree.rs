//! Fig 9 — throughput speedup of the K-ary sum tree + two-lock buffer
//! over the binary sum tree + single global lock, as a function of
//! fan-out K and buffer size N.
//!
//!     cargo bench --bench fig9_sumtree -- \
//!         [--sizes 1000,10000] [--fanouts 16,64,256] [--ops N] \
//!         [--json PATH] [--test]
//!
//! Protocol mirrors the paper (§VI-D): 4 threads, each running sampling
//! and priority updates against the shared buffer 1000 times, sizes
//! N ∈ {1e3, 1e4, 1e5}. Two views are reported:
//!   * real threads on this host (exercises the actual lock code; on a
//!     1-core container this measures critical-section length, not
//!     parallelism), and
//!   * the multicore DES projection at 4 cores (DESIGN.md substitution),
//!     which reproduces the paper's >4x speedups and the local optimum
//!     in K that shrinks as N grows.
//!
//! `--json PATH` writes the machine-readable sweep (`BENCH_sumtree.json`
//! via tools/bench_smoke.sh) with ratio verdicts: the DES speedup at
//! K = 64 (worst over sizes) and at the best K per size, both gated by
//! tools/bench_compare.py against the committed baseline. The real-
//! thread speedup is recorded for the trail but not gated — on shared
//! 1-core runners it measures critical-section length, not parallelism.

use pal_rl::replay::{
    GlobalLockReplay, PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch,
    Transition,
};
use pal_rl::sim::{simulate, Counter, Lock, Segment, Task};
use pal_rl::util::bench::Table;
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

const THREADS: usize = 4;
const OPS_PER_THREAD: usize = 1000;
const BATCH: usize = 32;

fn tr() -> Transition {
    Transition {
        obs: vec![0.5; 8],
        action: vec![0.1; 2],
        next_obs: vec![0.6; 8],
        reward: 1.0,
        done: false,
    }
}

/// Wall-clock of `threads` workers each doing `ops` sample+update rounds.
fn run_threads(buf: Arc<dyn ReplayBuffer>, threads: usize, ops: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                let mut rng = Rng::new(t as u64 + 1);
                let mut out = SampleBatch::default();
                for _ in 0..ops {
                    buf.sample(BATCH, &mut rng, &mut out);
                    let idx = out.indices.clone();
                    let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
                    buf.update_priorities(&idx, &tds);
                }
            });
        }
    });
    let total_ops = (threads * ops * 2) as f64; // sample + update per round
    total_ops / t0.elapsed().as_secs_f64()
}

/// Measure single-thread sample/update costs (drives the DES).
fn measure_op_costs(buf: &dyn ReplayBuffer, n: usize) -> (u64, u64) {
    let mut rng = Rng::new(9);
    let mut out = SampleBatch::default();
    let t0 = Instant::now();
    for _ in 0..400 {
        buf.sample(BATCH, &mut rng, &mut out);
    }
    let sample_ns = (t0.elapsed().as_nanos() as u64 / 400).max(1);
    let idx: Vec<usize> = (0..BATCH).map(|_| rng.below_usize(n)).collect();
    let tds = vec![0.7f32; BATCH];
    let t1 = Instant::now();
    for _ in 0..400 {
        buf.update_priorities(&idx, &tds);
    }
    let update_ns = (t1.elapsed().as_nanos() as u64 / 400).max(1);
    (sample_ns, update_ns)
}

/// DES projection of THREADS workers at `cores` cores.
fn des_throughput(sample_ns: u64, update_ns: u64, two_lock: bool, cores: usize) -> f64 {
    let tasks: Vec<Task> = (0..THREADS)
        .map(|_| Task {
            segments: if two_lock {
                // Two-lock + lazy writing: row copies leave the lock.
                vec![
                    Segment::locked(sample_ns * 6 / 10, Lock::GlobalTree),
                    Segment::cpu(sample_ns * 4 / 10),
                    Segment::locked(update_ns, Lock::GlobalTree),
                ]
            } else {
                // Global lock: everything inside.
                vec![
                    Segment::locked(sample_ns, Lock::GlobalTree),
                    Segment::locked(update_ns, Lock::GlobalTree),
                ]
            },
            counts_as: Counter::Consume,
        })
        .collect();
    let r = simulate(&tasks, cores, 300_000_000);
    r.consume_per_sec * 2.0 // two ops per cycle
}

/// One (N, K) measurement for the report and the JSON artifact.
struct Row {
    n: usize,
    k: usize,
    real_ops: f64,
    real_speedup: f64,
    des_ops: f64,
    des_speedup: f64,
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env()?;
    // `--test` = CI smoke: one small N, two fan-outs, tiny op counts —
    // exercises every code path (real threads + DES) in seconds.
    let smoke = a.flag("test");
    let default_sizes: &[usize] = if smoke { &[1_000] } else { &[1_000, 10_000, 100_000] };
    let sizes = a.usize_list("sizes", default_sizes)?;
    let default_fanouts: &[usize] = if smoke { &[16, 64] } else { &[16, 32, 64, 128, 256, 512] };
    let fanouts = a.usize_list("fanouts", default_fanouts)?;
    let ops_per_thread: usize = a.parse_or("ops", if smoke { 50 } else { OPS_PER_THREAD })?;

    println!("Fig 9 — K-ary + two-lock vs binary + global lock");
    println!("({THREADS} threads x {ops_per_thread} sample+update rounds, batch {BATCH})\n");

    let mut rows: Vec<Row> = Vec::new();
    let mut baselines: Vec<(usize, f64, f64)> = Vec::new(); // (n, real, des)
    for &n in &sizes {
        // Baseline: binary tree + single global lock.
        let base = Arc::new(GlobalLockReplay::new(n, 8, 2, 0.6, 0.4));
        for _ in 0..n {
            base.insert(&tr());
        }
        let (bs_ns, bu_ns) = measure_op_costs(base.as_ref(), n);
        let base_tput = run_threads(base, THREADS, ops_per_thread);
        let base_des = des_throughput(bs_ns, bu_ns, false, THREADS);
        baselines.push((n, base_tput, base_des));

        let mut table = Table::new(&[
            "K",
            "real ops/s",
            "real speedup",
            "DES@4c ops/s",
            "DES speedup",
        ]);
        let mut best_k = 0usize;
        let mut best_des = 0.0f64;
        for &k in &fanouts {
            let buf = Arc::new(PrioritizedReplay::new(PrioritizedConfig {
                capacity: n,
                obs_dim: 8,
                act_dim: 2,
                fanout: k,
                alpha: 0.6,
                beta: 0.4,
                lazy_writing: true,
                shards: 1,
            }));
            for _ in 0..n {
                buf.insert(&tr());
            }
            let (s_ns, u_ns) = measure_op_costs(buf.as_ref(), n);
            let tput = run_threads(buf, THREADS, ops_per_thread);
            let des = des_throughput(s_ns, u_ns, true, THREADS);
            if des > best_des {
                best_des = des;
                best_k = k;
            }
            rows.push(Row {
                n,
                k,
                real_ops: tput,
                real_speedup: tput / base_tput.max(1e-9),
                des_ops: des,
                des_speedup: des / base_des.max(1e-9),
            });
            table.row(vec![
                k.to_string(),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base_tput),
                format!("{des:.0}"),
                format!("{:.2}x", des / base_des),
            ]);
        }
        println!("N = {n} (baseline real {base_tput:.0} ops/s, DES {base_des:.0} ops/s):");
        table.print();
        println!("best fan-out by DES projection: K = {best_k}\n");
    }
    println!(
        "paper's shape: speedup > 4 at 4 threads; optimal K decreases as N\n\
         grows (K=256 @ N=1e3, K=128 @ N=1e4, K=64 @ N=1e5)."
    );

    // --- Verdicts ------------------------------------------------------
    // Worst-over-sizes DES speedup at the paper's reference fan-out
    // (K = 64) and at the per-size best K; K=64 may be absent in a
    // custom sweep, then that verdict is null and the compare skips it.
    let worst_over = |f: &dyn Fn(usize) -> Option<f64>| {
        let v = sizes.iter().filter_map(|&n| f(n)).fold(f64::INFINITY, f64::min);
        v.is_finite().then_some(v)
    };
    let des_k64 = worst_over(&|n| {
        rows.iter().find(|r| r.n == n && r.k == 64).map(|r| r.des_speedup)
    });
    let real_k64 = worst_over(&|n| {
        rows.iter().find(|r| r.n == n && r.k == 64).map(|r| r.real_speedup)
    });
    let des_best = worst_over(&|n| {
        let m = rows
            .iter()
            .filter(|r| r.n == n)
            .map(|r| r.des_speedup)
            .fold(f64::NEG_INFINITY, f64::max);
        m.is_finite().then_some(m)
    });
    if let Some(v) = des_k64 {
        println!(
            "\nverdict: DES speedup at K=64, worst over sizes = {v:.2}x — \
             target >= 1x [{}]",
            if v >= 1.0 { "OK" } else { "MISS" }
        );
    }
    if let Some(v) = des_best {
        println!(
            "verdict: DES speedup at best K, worst over sizes = {v:.2}x — \
             target >= 1x [{}]",
            if v >= 1.0 { "OK" } else { "MISS" }
        );
    }

    // --- Machine-readable output ---------------------------------------
    if let Some(path) = a.get("json") {
        let fmt_opt = |v: Option<f64>| match v {
            Some(v) => format!("{v:.3}"),
            None => "null".into(),
        };
        let mut j = String::from("{\n  \"bench\": \"fig9_sumtree\",\n");
        j.push_str(&format!(
            "  \"config\": {{\"threads\": {THREADS}, \"ops_per_thread\": {ops_per_thread}, \
             \"batch\": {BATCH}, \"sizes\": {sizes:?}, \"fanouts\": {fanouts:?}, \
             \"smoke\": {smoke}}},\n"
        ));
        j.push_str("  \"baselines\": [\n");
        for (i, (n, real, des)) in baselines.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"n\": {n}, \"real_ops_per_sec\": {real:.1}, \
                 \"des_ops_per_sec\": {des:.1}}}{}\n",
                if i + 1 < baselines.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n  \"rows\": [\n");
        for (i, r) in rows.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"n\": {}, \"k\": {}, \"real_ops_per_sec\": {:.1}, \
                 \"real_speedup\": {:.3}, \"des_ops_per_sec\": {:.1}, \
                 \"des_speedup\": {:.3}}}{}\n",
                r.n,
                r.k,
                r.real_ops,
                r.real_speedup,
                r.des_ops,
                r.des_speedup,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "  ],\n  \"verdicts\": {{\"des_speedup_k64_worst\": {}, \
             \"des_speedup_best_worst\": {}, \"real_speedup_k64_worst\": {}}},\n",
            fmt_opt(des_k64),
            fmt_opt(des_best),
            fmt_opt(real_k64),
        ));
        j.push_str(
            "  \"gate\": {\"des_speedup_k64_worst\": {\"floor\": 1.0, \"tolerance\": 0.5}, \
             \"des_speedup_best_worst\": {\"floor\": 1.0, \"tolerance\": 0.5}}\n}\n",
        );
        std::fs::write(path, j)?;
        eprintln!("[fig9_sumtree] results written to {path}");
    }
    Ok(())
}
