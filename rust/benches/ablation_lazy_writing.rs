//! Ablation — lazy writing ON vs OFF (DESIGN.md §6 design choice).
//!
//!     cargo bench --bench ablation_lazy_writing -- [--test]
//!
//! Same K-ary two-lock buffer; the only difference is whether the
//! storage copy happens outside the locks (paper §IV-D2) or inside the
//! global tree lock. Workload: 2 inserter threads + 2 sampler/updater
//! threads sharing one buffer — the regime lazy writing was designed
//! for. Wide rows make the copy matter.
//!
//! Two paths are swept at every row width:
//!   * direct — threads call the bare `PrioritizedReplay`;
//!   * service — the same workload through `TrajectoryWriter` →
//!     `Table` → `SamplerHandle`, so the ablation also covers the
//!     admission-control surface production code actually uses.
//!
//! `--test` runs a small smoke configuration (CI).

use pal_rl::replay::{PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition};
use pal_rl::service::{ItemKind, RateLimiter, ReplayService, SampleOutcome, Table, WriterStep};
use pal_rl::util::bench::Table as Report;
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

const ACT_DIM: usize = 4;
const CAPACITY: usize = 50_000;

fn mk_buffer(lazy: bool, obs_dim: usize) -> Arc<dyn ReplayBuffer> {
    Arc::new(PrioritizedReplay::new(PrioritizedConfig {
        capacity: CAPACITY,
        obs_dim,
        act_dim: ACT_DIM,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: lazy,
        shards: 1,
    }))
}

fn mk_transition(obs_dim: usize) -> Transition {
    Transition {
        obs: vec![0.5; obs_dim],
        action: vec![0.1; ACT_DIM],
        next_obs: vec![0.6; obs_dim],
        reward: 1.0,
        done: false,
    }
}

fn mk_step(obs_dim: usize) -> WriterStep {
    let t = mk_transition(obs_dim);
    WriterStep {
        obs: t.obs,
        action: t.action,
        next_obs: t.next_obs,
        reward: t.reward,
        done: false,
        truncated: false,
    }
}

/// Direct path: 2 inserters + 2 sampler/updaters on the bare buffer.
fn run_direct(lazy: bool, obs_dim: usize, inserts: usize, rounds: usize) -> (f64, f64) {
    let buf = mk_buffer(lazy, obs_dim);
    let t = mk_transition(obs_dim);
    for _ in 0..inserts {
        buf.insert(&t);
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..2 {
            let buf = Arc::clone(&buf);
            let tr = t.clone();
            s.spawn(move || {
                for _ in 0..inserts {
                    buf.insert(&tr);
                }
            });
        }
        for tid in 0..2 {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                let mut rng = Rng::new(tid);
                let mut out = SampleBatch::default();
                for _ in 0..rounds {
                    buf.sample(64, &mut rng, &mut out);
                    let tds: Vec<f32> = out.indices.iter().map(|_| rng.f32()).collect();
                    buf.update_priorities(&out.indices.clone(), &tds);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    ((2 * inserts) as f64 / secs, (2 * rounds) as f64 / secs)
}

/// Service path: the same 2+2 workload through `TrajectoryWriter` →
/// `Table` → `SamplerHandle`, so lazy-on/off is also measured with the
/// admission poll and table accounting in the loop.
fn run_service(lazy: bool, obs_dim: usize, inserts: usize, rounds: usize) -> (f64, f64) {
    let table = Table::new(
        "replay",
        ItemKind::OneStep,
        mk_buffer(lazy, obs_dim),
        RateLimiter::Unlimited { min_size_to_sample: 64 },
    );
    let svc = Arc::new(ReplayService::new(vec![table]).expect("valid service"));
    {
        let mut w = svc.writer(99);
        for _ in 0..inserts {
            w.append(mk_step(obs_dim));
        }
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tid in 0..2 {
            let svc = Arc::clone(&svc);
            s.spawn(move || {
                let mut w = svc.writer(tid);
                for _ in 0..inserts {
                    w.append(mk_step(obs_dim));
                }
            });
        }
        for tid in 0..2 {
            let svc = Arc::clone(&svc);
            s.spawn(move || {
                let sampler = svc.default_sampler();
                let mut rng = Rng::new(tid);
                let mut out = SampleBatch::default();
                for _ in 0..rounds {
                    if let SampleOutcome::Sampled = sampler.try_sample(64, &mut rng, &mut out) {
                        let tds: Vec<f32> = out.indices.iter().map(|_| rng.f32()).collect();
                        sampler.update_priorities(&out.indices.clone(), &tds);
                    }
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    ((2 * inserts) as f64 / secs, (2 * rounds) as f64 / secs)
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env()?;
    let smoke = a.flag("test");
    let obs_dims: &[usize] = if smoke { &[8, 256] } else { &[8, 64, 256, 1024] };
    let inserts: usize = if smoke { 2_000 } else { 20_000 };
    let rounds: usize = if smoke { 150 } else { 1_500 };

    println!("Ablation — lazy writing (copies outside locks) vs copy-under-lock\n");
    for (path, run) in [
        ("direct", run_direct as fn(bool, usize, usize, usize) -> (f64, f64)),
        ("service", run_service),
    ] {
        println!("{path} path:");
        let mut t = Report::new(&[
            "row width (f32)",
            "lazy ins/s",
            "locked ins/s",
            "lazy rounds/s",
            "locked rounds/s",
            "insert speedup",
        ]);
        for &obs_dim in obs_dims {
            let (li, lr) = run(true, obs_dim, inserts, rounds);
            let (ni, nr) = run(false, obs_dim, inserts, rounds);
            if smoke {
                // Smoke mode gates only the deterministic part: both
                // variants moved data on both paths.
                assert!(li > 0.0 && ni > 0.0, "{path}: no inserts at width {obs_dim}");
            }
            t.row(vec![
                (2 * obs_dim + ACT_DIM + 2).to_string(),
                format!("{li:.0}"),
                format!("{ni:.0}"),
                format!("{lr:.0}"),
                format!("{nr:.0}"),
                format!("{:.2}x", li / ni.max(1e-9)),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "expected: the wider the transition row, the more the copy-under-\n\
         lock variant serializes samplers behind inserters; lazy writing\n\
         keeps sampling throughput flat as rows grow (paper §IV-D2) — on\n\
         both the direct and the service path."
    );
    Ok(())
}
