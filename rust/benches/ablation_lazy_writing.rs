//! Ablation — lazy writing ON vs OFF (DESIGN.md §6 design choice).
//!
//! Same K-ary two-lock buffer; the only difference is whether the
//! storage copy happens outside the locks (paper §IV-D2) or inside the
//! global tree lock. Workload: 2 inserter threads + 2 sampler/updater
//! threads sharing one buffer — the regime lazy writing was designed
//! for. Wide rows make the copy matter.

use pal_rl::replay::{PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition};
use pal_rl::util::bench::Table;
use pal_rl::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn run(lazy: bool, obs_dim: usize) -> (f64, f64) {
    let buf = Arc::new(PrioritizedReplay::new(PrioritizedConfig {
        capacity: 50_000,
        obs_dim,
        act_dim: 4,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: lazy,
        shards: 1,
    }));
    let t = Transition {
        obs: vec![0.5; obs_dim],
        action: vec![0.1; 4],
        next_obs: vec![0.6; obs_dim],
        reward: 1.0,
        done: false,
    };
    for _ in 0..20_000 {
        buf.insert(&t);
    }
    let inserts = 20_000usize;
    let rounds = 1_500usize;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..2 {
            let buf = Arc::clone(&buf);
            let tr = t.clone();
            s.spawn(move || {
                for _ in 0..inserts {
                    buf.insert(&tr);
                }
            });
        }
        for tid in 0..2 {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                let mut rng = Rng::new(tid);
                let mut out = SampleBatch::default();
                for _ in 0..rounds {
                    buf.sample(64, &mut rng, &mut out);
                    let tds: Vec<f32> = out.indices.iter().map(|_| rng.f32()).collect();
                    buf.update_priorities(&out.indices.clone(), &tds);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    ((2 * inserts) as f64 / secs, (2 * rounds) as f64 / secs)
}

fn main() {
    println!("Ablation — lazy writing (copies outside locks) vs copy-under-lock\n");
    let mut t = Table::new(&[
        "row width (f32)",
        "lazy ins/s",
        "locked ins/s",
        "lazy rounds/s",
        "locked rounds/s",
        "insert speedup",
    ]);
    for &obs_dim in &[8usize, 64, 256, 1024] {
        let (li, lr) = run(true, obs_dim);
        let (ni, nr) = run(false, obs_dim);
        t.row(vec![
            (2 * obs_dim + 4 + 2).to_string(),
            format!("{li:.0}"),
            format!("{ni:.0}"),
            format!("{lr:.0}"),
            format!("{nr:.0}"),
            format!("{:.2}x", li / ni),
        ]);
    }
    t.print();
    println!(
        "\nexpected: the wider the transition row, the more the copy-under-\n\
         lock variant serializes samplers behind inserters; lazy writing\n\
         keeps sampling throughput flat as rows grow (paper §IV-D2)."
    );
}
