//! Fig 12 — design-space exploration: profiled throughput curves f_a(x),
//! f_l(x) and the Eq.-5 core allocation, for a fast and a slow
//! environment and two target ratios.

use pal_rl::dse::{explore, CostProfile};
use pal_rl::util::bench::Table;

fn main() {
    println!("Fig 12 — DSE throughput curves and core allocation\n");
    let cores = 8usize;

    for (algo, env) in [("dqn", "CartPole-v1"), ("sac", "LunarLanderLite-v0")] {
        let p = CostProfile::representative(algo, env);
        let mut t = Table::new(&["cores", "f_a (collect/s)", "f_l (consume/s)"]);
        for x in 1..=cores {
            t.row(vec![
                x.to_string(),
                format!("{:.0}", p.f_a(x)),
                format!("{:.0}", p.f_l(x)),
            ]);
        }
        println!("{algo} @ {env}:");
        t.print();

        for ratio in [1.0f64, 4.0] {
            let plan = explore(&p, cores, ratio);
            println!(
                "  Eq.5 @ ratio {ratio}: {} actors + {} learners \
                 (collect {:.0}/s, consume {:.0}/s, mismatch {:.1}%)",
                plan.actors,
                plan.learners,
                plan.collect_throughput,
                plan.consume_throughput,
                plan.mismatch * 100.0
            );
        }
        println!();
    }
    println!(
        "paper's shape: f_a grows ~linearly with actor cores; f_l saturates\n\
         (accelerator-bound); the intersection under the ratio constraint\n\
         picks the allocation. Exhaustive search is O(M^2)."
    );
}
