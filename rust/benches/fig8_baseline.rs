//! Fig 8 — end-to-end comparison with the baseline framework
//! (RLlib-substitute: same algorithms, binary sum tree behind one global
//! lock, synchronized sampling) on DQN / DDPG / SAC at 1–8 cores.
//!
//! The paper reports 3.1x–10.8x convergence-time reduction, growing with
//! core count until the GPU saturates. We reproduce the *shape* two ways:
//!   * real runs at 1 worker pair on this host (PAL vs baseline buffer,
//!     same budget — isolates the buffer + sync design), and
//!   * the multicore DES projection at 1–8 cores, driven by per-op costs
//!     measured from the real runs.

use pal_rl::coordinator::{train, BufferKind, TrainConfig};
use pal_rl::dse::CostProfile;
use pal_rl::util::bench::Table;

fn real_run(algo: &str, env: &str, buffer: BufferKind, steps: usize) -> anyhow::Result<f64> {
    let mut cfg = TrainConfig::new(algo, env);
    cfg.total_env_steps = steps;
    cfg.warmup_steps = 200;
    cfg.update_interval = if algo == "dqn" { 1.0 } else { 2.0 };
    cfg.buffer = buffer;
    cfg.actor_lead = 0; // free-run: throughput measurement
    cfg.seed = 11;
    let r = train(&cfg)?;
    Ok(r.env_steps_per_sec)
}

fn main() -> anyhow::Result<()> {
    // `--test` = CI smoke: DES projection only (the real runs need
    // artifacts and a minute of wall clock).
    let test_mode = std::env::args().any(|a| a == "--test");
    let have_artifacts =
        !test_mode && std::path::Path::new("artifacts/manifest.json").exists();
    println!("Fig 8 — ours vs baseline framework (global-lock buffer)\n");

    // ---- real single-pair runs on this host -------------------------
    if have_artifacts {
        let mut t = Table::new(&["algo", "PAL steps/s", "baseline steps/s", "speedup"]);
        for (algo, env) in [("dqn", "CartPole-v1"), ("ddpg", "Pendulum-v1"),
                            ("sac", "Pendulum-v1")] {
            let ours = real_run(algo, env, BufferKind::PalKary, 2_000)?;
            let base = real_run(algo, env, BufferKind::GlobalLock, 2_000)?;
            t.row(vec![
                algo.into(),
                format!("{ours:.0}"),
                format!("{base:.0}"),
                format!("{:.2}x", ours / base),
            ]);
        }
        println!("real runs, 1 actor + 1 learner on this host:");
        t.print();
        println!();
    } else {
        println!("(artifacts missing — skipping real runs; run `make artifacts`)\n");
    }

    // ---- DES projection at 1..8 cores --------------------------------
    // PAL: two-lock buffer, asynchronous actors, best Eq.5 split.
    // Baseline (RLlib substitute): global-lock buffer + interpreted
    // framework overheads + synchronized collection (DESIGN.md §4).
    // Metric: balanced training throughput min(collect, ratio·consume) —
    // convergence time follows the paced pipeline's slower side.
    for algo in ["dqn", "ddpg", "sac"] {
        let env = if algo == "dqn" { "CartPole-v1" } else { "Pendulum-v1" };
        let mut pal_p = CostProfile::representative(algo, env);
        pal_p.serialized_accel = true;
        pal_p.accel_slots = 4; // GTX-1650-class: a few batches in flight
        let mut base_p = CostProfile::rllib_like(algo, env);
        base_p.serialized_accel = true;
        base_p.accel_slots = 4;
        let ratio = 1.0;
        let mut t = Table::new(&[
            "cores", "PAL (a+l)", "PAL steps/s", "RLlib-sub steps/s", "speedup",
        ]);
        for cores in [1usize, 2, 4, 6, 8] {
            let (pa, pl, pal) = pal_p.best_balanced(cores, ratio);
            let (_, _, base) = base_p.best_balanced(cores, ratio);
            t.row(vec![
                cores.to_string(),
                format!("{pa}+{pl}"),
                format!("{pal:.0}"),
                format!("{base:.0}"),
                format!("{:.2}x", pal / base.max(1e-9)),
            ]);
        }
        println!("DES projection — {algo} ({env}):");
        t.print();
        println!();
    }
    println!(
        "paper's shape: speedup grows with cores (3.1x → 10.8x) then\n\
         saturates when the accelerator becomes the bottleneck."
    );
    Ok(())
}
