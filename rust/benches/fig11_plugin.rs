//! Fig 11 — speedup from plugging the PAL buffer into existing
//! frameworks, across five algorithms.
//!
//! The paper swaps its C++ buffer into tianshou (CPython-extension
//! buffer), PFRL and rlpyt (pure-Python buffers) and measures sequential
//! end-to-end training speedups of 1.1x–2.1x, shrinking as the
//! algorithm's compute share grows. We reproduce with the emulated
//! framework buffers (`replay::emulated`, structural-cost emulations
//! documented in DESIGN.md) inside the same sequential Alg-1 loop, with
//! per-algorithm learn costs measured from the real compiled graphs.

use pal_rl::replay::{
    PrioritizedConfig, PrioritizedReplay, PyBindBinaryReplay, PySumTreeReplay,
    ReplayBuffer, SampleBatch, Transition,
};
use pal_rl::util::bench::Table;
use pal_rl::util::rng::Rng;
use std::time::Instant;

/// Per-learn-step compute cost (ns) by algorithm, measured from the
/// compiled learn graphs on this host (see EXPERIMENTS.md §Fig11).
/// Emulated with a spin so the bench also runs without artifacts.
const ALGO_LEARN_NS: &[(&str, u64)] = &[
    ("dqn", 750_000),
    ("ddqn", 800_000),
    ("ddpg", 1_500_000),
    ("td3", 2_000_000),
    ("sac", 2_400_000),
];

fn spin_ns(ns: u64) {
    let t0 = Instant::now();
    while (t0.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

fn tr(v: f32) -> Transition {
    Transition {
        obs: vec![v; 8],
        action: vec![v; 2],
        next_obs: vec![v; 8],
        reward: v,
        done: false,
    }
}

/// Sequential Algorithm-1 loop: insert every step, sample+learn+update
/// every `update_interval` steps. Returns steps/sec.
fn sequential_loop(buf: &dyn ReplayBuffer, learn_ns: u64, steps: usize, prefill: usize) -> f64 {
    let mut rng = Rng::new(5);
    let mut out = SampleBatch::default();
    // Pre-fill to a realistic occupancy so tree depth matters.
    for i in 0..prefill {
        buf.insert(&tr(i as f32));
    }
    let t0 = Instant::now();
    for i in 0..steps {
        buf.insert(&tr(i as f32));
        if i % 4 == 0 {
            // env-step cost placeholder (cheap classic-control step)
            spin_ns(700);
        }
        if buf.sample(32, &mut rng, &mut out) {
            spin_ns(learn_ns / 4); // update_interval 4: amortized learn
            if i % 4 == 0 {
                let idx = out.indices.clone();
                let tds: Vec<f32> = idx.iter().map(|_| rng.f32()).collect();
                buf.update_priorities(&idx, &tds);
            }
        }
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    // `--test` = CI smoke: small loop + shallow pre-fill, same paths.
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("Fig 11 — plugging the PAL buffer into framework-style loops\n");
    let steps = if test_mode { 200usize } else { 3_000usize };
    let cap = if test_mode { 10_000usize } else { 100_000usize };
    let prefill = if test_mode { 2_000usize } else { 30_000usize };

    let mut t = Table::new(&[
        "algo",
        "vs python-sumtree buffer",
        "vs cpython-binding buffer",
    ]);
    for &(algo, learn_ns) in ALGO_LEARN_NS {
        let ours = PrioritizedReplay::new(PrioritizedConfig {
            capacity: cap,
            obs_dim: 8,
            act_dim: 2,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        });
        let pure_py = PySumTreeReplay::new(cap, 8, 2, 0.6, 0.4);
        let binding = PyBindBinaryReplay::new(cap, 8, 2, 0.6, 0.4);

        let ours_tput = sequential_loop(&ours, learn_ns, steps, prefill);
        let py_tput = sequential_loop(&pure_py, learn_ns, steps, prefill);
        let bind_tput = sequential_loop(&binding, learn_ns, steps, prefill);
        t.row(vec![
            algo.into(),
            format!("{:.2}x", ours_tput / py_tput),
            format!("{:.2}x", ours_tput / bind_tput),
        ]);
    }
    t.print();
    println!(
        "\npaper's shape: 1.1x–2.1x; the speedup SHRINKS as the algorithm's\n\
         compute share grows (sac < td3 < ddpg < ddqn < dqn), and the\n\
         CPython-extension framework (tianshou) gains least."
    );
}
