//! Replay-service throughput sweep: writers × table layouts × rate
//! limiters, against the direct-buffer path the service replaced.
//!
//!     cargo bench --bench fig_service -- \
//!         [--writers 1,2,4] [--samplers N] [--steps N] [--capacity N] \
//!         [--json PATH] [--test]
//!
//! Protocol: W writer threads each push `steps` synthetic env steps
//! (64-step episodes) while S sampler threads draw batches and feed
//! priorities back, the learner hot loop with the PJRT compute stripped
//! away. The service path goes through `TrajectoryWriter` →
//! `Table` → `RateLimiter`; the direct path calls the bare buffer the
//! way the coordinator did before the service existed.
//!
//! Acceptance: the `service 1step / unlimited` row must hold ≥ 0.9× the
//! direct path's writer throughput (the service layer is one admission
//! poll + one counter bump per op — no measurable regression). Rate-
//! limited rows are *expected* to stall a side; their stall counters
//! are part of the printed output, not a regression.
//!
//! `--test` runs a small smoke configuration (CI). `--json PATH` writes
//! the machine-readable sweep (`BENCH_service.json` via
//! tools/bench_smoke.sh); its gated verdict is the service/direct parity
//! ratio (worst over writer counts) with a deliberately loose floor —
//! shared 1-core runners are too noisy for the 0.9x in-program target,
//! but a parity collapse (service path serializing on a new lock, say)
//! still trips the gate.

use pal_rl::replay::{
    PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition,
};
use pal_rl::service::{
    ItemKind, RateLimiter, ReplayService, SampleOutcome, SampleToInsertRatio, Table,
    WriterStep,
};
use pal_rl::util::bench::Table as Report;
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

const BATCH: usize = 32;
const OBS_DIM: usize = 8;
const ACT_DIM: usize = 2;
const EPISODE_LEN: usize = 64;

fn mk_buffer(capacity: usize, obs_dim: usize, act_dim: usize) -> Arc<dyn ReplayBuffer> {
    Arc::new(PrioritizedReplay::new(PrioritizedConfig {
        capacity,
        obs_dim,
        act_dim,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    }))
}

fn mk_step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32; OBS_DIM],
        action: vec![0.1; ACT_DIM],
        next_obs: vec![i as f32 + 1.0; OBS_DIM],
        reward: 1.0,
        done: i % EPISODE_LEN == EPISODE_LEN - 1,
        truncated: false,
    }
}

fn mk_transition(i: usize) -> Transition {
    let s = mk_step(i);
    Transition {
        obs: s.obs,
        action: s.action,
        next_obs: s.next_obs,
        reward: s.reward,
        done: s.done,
    }
}

/// One benchmark configuration: a table layout + a limiter, or the
/// direct bare-buffer path when `tables` is empty.
struct Config {
    name: &'static str,
    tables: Vec<(&'static str, ItemKind)>,
    limiter: RateLimiter,
}

fn unlimited(min_size: usize) -> RateLimiter {
    RateLimiter::Unlimited { min_size_to_sample: min_size }
}

fn ratio(sigma: f64, min_size: usize) -> RateLimiter {
    RateLimiter::SampleToInsertRatio(
        SampleToInsertRatio::new(sigma, min_size, sigma.max(1.0) * min_size.max(1) as f64)
            .expect("valid limiter"),
    )
}

struct RunResult {
    writer_steps_per_sec: f64,
    batches_per_sec: f64,
    insert_stalls: usize,
    sample_stalls: usize,
    /// Items landed in the (default) table — the smoke mode's
    /// deterministic accounting check.
    default_inserts: usize,
    granted_batches: usize,
}

/// Direct path: W threads insert into the bare buffer, S threads
/// sample/update until the writers finish.
fn run_direct(writers: usize, samplers: usize, steps: usize, capacity: usize) -> RunResult {
    let buf = mk_buffer(capacity, OBS_DIM, ACT_DIM);
    let done = AtomicBool::new(false);
    let batches = AtomicUsize::new(0);
    let finished_writers = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut writer_secs = 0.0f64;
    std::thread::scope(|s| {
        for tid in 0..writers {
            let buf = Arc::clone(&buf);
            let finished = &finished_writers;
            s.spawn(move || {
                for i in 0..steps {
                    buf.insert_from(tid, &mk_transition(i));
                }
                finished.fetch_add(1, Ordering::Relaxed);
            });
        }
        for tid in 0..samplers {
            let buf = Arc::clone(&buf);
            let done = &done;
            let batches = &batches;
            s.spawn(move || {
                let mut rng = Rng::new(100 + tid as u64);
                let mut out = SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    if buf.sample(BATCH, &mut rng, &mut out) {
                        batches.fetch_add(1, Ordering::Relaxed);
                        let idx = out.indices.clone();
                        let tds: Vec<f32> = idx.iter().map(|_| rng.f32() * 2.0).collect();
                        buf.update_priorities(&idx, &tds);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
        while finished_writers.load(Ordering::Relaxed) < writers {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        writer_secs = t0.elapsed().as_secs_f64();
        done.store(true, Ordering::Relaxed);
    });
    RunResult {
        writer_steps_per_sec: (writers * steps) as f64 / writer_secs,
        batches_per_sec: batches.load(Ordering::Relaxed) as f64 / writer_secs,
        insert_stalls: 0,
        sample_stalls: 0,
        default_inserts: writers * steps,
        granted_batches: batches.load(Ordering::Relaxed),
    }
}

/// Service path: writers go through `TrajectoryWriter`, samplers
/// through `SamplerHandle` on the first table.
fn run_service(
    cfg: &Config,
    writers: usize,
    samplers: usize,
    steps: usize,
    capacity: usize,
) -> RunResult {
    let tables: Vec<Table> = cfg
        .tables
        .iter()
        .map(|&(name, kind)| {
            let m = kind.dim_multiplier();
            Table::new(
                name,
                kind,
                mk_buffer(capacity, OBS_DIM * m, ACT_DIM * m),
                cfg.limiter,
            )
        })
        .collect();
    let svc = Arc::new(ReplayService::new(tables).expect("valid service"));
    let done = AtomicBool::new(false);
    let batches = AtomicUsize::new(0);
    let finished_writers = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut writer_secs = 0.0f64;
    std::thread::scope(|s| {
        for tid in 0..writers {
            let svc = Arc::clone(&svc);
            let finished = &finished_writers;
            s.spawn(move || {
                let mut w = svc.writer(tid);
                let mut appended = 0usize;
                while appended < steps {
                    if w.throttled() {
                        std::thread::yield_now();
                        continue;
                    }
                    w.append(mk_step(appended));
                    appended += 1;
                }
                finished.fetch_add(1, Ordering::Relaxed);
            });
        }
        for tid in 0..samplers {
            let svc = Arc::clone(&svc);
            let done = &done;
            let batches = &batches;
            s.spawn(move || {
                let sampler = svc.default_sampler();
                let mut rng = Rng::new(100 + tid as u64);
                let mut out = SampleBatch::default();
                while !done.load(Ordering::Relaxed) {
                    match sampler.try_sample(BATCH, &mut rng, &mut out) {
                        SampleOutcome::Sampled => {
                            batches.fetch_add(1, Ordering::Relaxed);
                            let idx = out.indices.clone();
                            let tds: Vec<f32> =
                                idx.iter().map(|_| rng.f32() * 2.0).collect();
                            sampler.update_priorities(&idx, &tds);
                        }
                        _ => std::thread::yield_now(),
                    }
                }
            });
        }
        while finished_writers.load(Ordering::Relaxed) < writers {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        writer_secs = t0.elapsed().as_secs_f64();
        done.store(true, Ordering::Relaxed);
    });
    let snap = svc.default_table().stats_snapshot();
    RunResult {
        writer_steps_per_sec: (writers * steps) as f64 / writer_secs,
        batches_per_sec: batches.load(Ordering::Relaxed) as f64 / writer_secs,
        insert_stalls: snap.insert_stalls,
        sample_stalls: snap.sample_stalls,
        default_inserts: snap.inserts,
        granted_batches: batches.load(Ordering::Relaxed),
    }
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env()?;
    let smoke = a.flag("test");
    let default_writers: &[usize] = if smoke { &[2] } else { &[1, 2, 4] };
    let writer_list = a.usize_list("writers", default_writers)?;
    let samplers: usize = a.parse_or("samplers", if smoke { 1 } else { 2 })?;
    let steps: usize = a.parse_or("steps", if smoke { 1_500 } else { 20_000 })?;
    let capacity: usize = a.parse_or("capacity", if smoke { 8_192 } else { 65_536 })?;
    let min_size = (capacity / 32).max(BATCH);

    let configs = vec![
        Config { name: "direct 1step (no service)", tables: vec![], limiter: unlimited(min_size) },
        Config {
            name: "service 1step / unlimited",
            tables: vec![("replay", ItemKind::OneStep)],
            limiter: unlimited(min_size),
        },
        Config {
            name: "service nstep:3 / unlimited",
            tables: vec![("replay", ItemKind::NStep { n: 3, gamma: 0.99 })],
            limiter: unlimited(min_size),
        },
        Config {
            name: "service 3 tables / unlimited",
            tables: vec![
                ("replay", ItemKind::OneStep),
                ("multi", ItemKind::NStep { n: 3, gamma: 0.99 }),
                ("traj", ItemKind::Sequence { len: 8 }),
            ],
            limiter: unlimited(min_size),
        },
        Config {
            name: "service 1step / sigma=1",
            tables: vec![("replay", ItemKind::OneStep)],
            limiter: ratio(1.0, min_size),
        },
        Config {
            name: "service 1step / sigma=0.125",
            tables: vec![("replay", ItemKind::OneStep)],
            limiter: ratio(0.125, min_size),
        },
    ];

    println!(
        "Replay service throughput (writers x tables x limiter), {} sampler thread(s), \
         {} steps/writer, capacity {}{}\n",
        samplers,
        steps,
        capacity,
        if smoke { " [smoke]" } else { "" },
    );

    let mut report = Report::new(&[
        "config", "writers", "steps/s", "batches/s", "stall i", "stall s", "vs direct",
    ]);
    // (writers, direct steps/s) baselines for the parity column.
    let mut direct_base: Vec<(usize, f64)> = Vec::new();
    let mut parity: Vec<(usize, f64)> = Vec::new();
    // (config, writers, result, vs-direct) for the JSON artifact.
    let mut jrows: Vec<(&'static str, usize, RunResult, f64)> = Vec::new();
    for &w in &writer_list {
        for cfg in &configs {
            let r = if cfg.tables.is_empty() {
                run_direct(w, samplers, steps, capacity)
            } else {
                run_service(cfg, w, samplers, steps, capacity)
            };
            if cfg.tables.is_empty() {
                direct_base.push((w, r.writer_steps_per_sec));
            }
            let base = direct_base
                .iter()
                .find(|&&(w0, _)| w0 == w)
                .map_or(r.writer_steps_per_sec, |&(_, b)| b);
            let vs = r.writer_steps_per_sec / base.max(1e-9);
            if cfg.name == "service 1step / unlimited" {
                parity.push((w, vs));
            }
            if smoke {
                // Smoke mode (the CI gate) enforces the DETERMINISTIC
                // part: every configuration must actually move data
                // through the service. The perf parity verdict below
                // stays advisory — shared CI runners are too noisy to
                // gate on a throughput ratio.
                assert!(
                    r.granted_batches > 0,
                    "{}: samplers were starved in smoke mode",
                    cfg.name
                );
                // Every step starts at least one item except an N-step
                // writer's unfinished tail window (< n steps).
                assert!(
                    r.default_inserts >= w * steps.saturating_sub(3),
                    "{}: {} items for {} writer steps",
                    cfg.name,
                    r.default_inserts,
                    w * steps
                );
            }
            report.row(vec![
                cfg.name.to_string(),
                w.to_string(),
                format!("{:.0}", r.writer_steps_per_sec),
                format!("{:.0}", r.batches_per_sec),
                r.insert_stalls.to_string(),
                r.sample_stalls.to_string(),
                format!("{vs:.2}x"),
            ]);
            jrows.push((cfg.name, w, r, vs));
        }
    }
    report.print();

    // --- Acceptance verdict -------------------------------------------
    let worst = parity
        .iter()
        .fold(f64::INFINITY, |acc, &(_, v)| acc.min(v));
    println!(
        "\nverdict: service 1step/unlimited vs direct path, worst over writer counts \
         = {worst:.2}x — target >= 0.90x [{}]",
        if worst >= 0.90 { "OK" } else { "MISS" }
    );
    println!(
        "(rate-limited rows stall by design; their stall columns are the limiter \
         doing its job, not a regression)"
    );

    // --- Machine-readable output ---------------------------------------
    if let Some(path) = a.get("json") {
        let parity_worst = worst.is_finite().then_some(worst);
        let fmt_opt = |v: Option<f64>| match v {
            Some(v) => format!("{v:.3}"),
            None => "null".into(),
        };
        let mut j = String::from("{\n  \"bench\": \"fig_service\",\n");
        j.push_str(&format!(
            "  \"config\": {{\"writers\": {writer_list:?}, \"samplers\": {samplers}, \
             \"steps\": {steps}, \"capacity\": {capacity}, \"batch\": {BATCH}, \
             \"smoke\": {smoke}}},\n"
        ));
        j.push_str("  \"rows\": [\n");
        for (i, (name, w, r, vs)) in jrows.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"config\": \"{name}\", \"writers\": {w}, \
                 \"writer_steps_per_sec\": {:.1}, \"batches_per_sec\": {:.1}, \
                 \"insert_stalls\": {}, \"sample_stalls\": {}, \"vs_direct\": {vs:.3}}}{}\n",
                r.writer_steps_per_sec,
                r.batches_per_sec,
                r.insert_stalls,
                r.sample_stalls,
                if i + 1 < jrows.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "  ],\n  \"verdicts\": {{\"service_parity_worst\": {}}},\n",
            fmt_opt(parity_worst),
        ));
        j.push_str(
            "  \"gate\": {\"service_parity_worst\": {\"floor\": 0.25, \"tolerance\": 0.5}}\n}\n",
        );
        std::fs::write(path, j)?;
        eprintln!("[fig_service] results written to {path}");
    }
    Ok(())
}
