//! Remote replay data-path throughput: batched appends × writers ×
//! pipelined sample prefetch over a REAL Unix-domain socket, against
//! the in-process path.
//!
//!     cargo bench --bench fig_remote -- \
//!         [--writers 1,2,4] [--batches 1,16,64] [--steps N] \
//!         [--rounds N] [--learner-batch 64] [--capacity N] \
//!         [--json PATH] [--test]
//!
//! Protocol, append side: W writer threads each ship `steps` synthetic
//! env steps through a `RemoteWriter` with client-side batch size B
//! (one `Append` RPC per B steps; B = 1 is the pre-batching
//! one-RPC-per-step wire behaviour). The in-process rows run the same
//! loop through a `TrajectoryWriter` as the upper bound.
//!
//! Protocol, sample side: one learner connection draws
//! `--learner-batch`-sized batches and feeds priorities back, prefetch
//! off (two serial round-trips per iteration) vs on (the next `Sample`
//! rides behind each `UpdatePriorities`, so `try_sample` only reads an
//! already-travelling response). The visible sample wait is timed
//! per-iteration.
//!
//! Protocol, mesh side: one mesh learner over two replay servers runs
//! the same draw-and-update loop with the level-1 mass adverts either
//! re-polled every draw (`--mass-ttl` 0, the lockstep-deterministic
//! mode) or cached for a few milliseconds, and reports the sampler's
//! RPC counters (mass probes, sample calls) alongside throughput — the
//! fan-out the TTL cache exists to shrink.
//!
//! Verdicts (advisory in --test mode — CI runners are too noisy to
//! gate on wall-clock): batch 16 must lift append steps/s ≥ 5× over
//! batch 1, and prefetch must hide ≥ 50% of the per-batch sample wait.
//!
//! `--json PATH` writes the machine-readable results
//! (`BENCH_remote.json` via tools/bench_remote.sh) so later PRs have a
//! perf baseline to diff against.

use pal_rl::remote::{
    ConnectionPolicy, Endpoint, MeshSampler, RemoteClient, RemoteSampler, RemoteWriter,
    ReplayServer, Request,
};
use pal_rl::replay::{PrioritizedConfig, PrioritizedReplay, SampleBatch};
use pal_rl::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimiter, ReplayService, SampleOutcome,
    Table, WriterStep,
};
use pal_rl::util::bench::Table as Report;
use pal_rl::util::cli::Args;
use pal_rl::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const OBS_DIM: usize = 8;
const ACT_DIM: usize = 2;
const EPISODE_LEN: usize = 64;

fn mk_service(capacity: usize) -> Arc<ReplayService> {
    let buffer = Arc::new(PrioritizedReplay::new(PrioritizedConfig {
        capacity,
        obs_dim: OBS_DIM,
        act_dim: ACT_DIM,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    }));
    Arc::new(
        ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            buffer,
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .expect("valid service"),
    )
}

fn mk_step(i: usize) -> WriterStep {
    WriterStep {
        obs: vec![i as f32; OBS_DIM],
        action: vec![0.1; ACT_DIM],
        next_obs: vec![i as f32 + 1.0; OBS_DIM],
        reward: 1.0,
        done: i % EPISODE_LEN == EPISODE_LEN - 1,
        truncated: false,
    }
}

/// Bind a fresh server for one configuration; the caller shuts it down.
fn start_server(service: Arc<ReplayService>) -> (PathBuf, std::thread::JoinHandle<()>) {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let path = std::env::temp_dir().join(format!(
        "pal_fig_remote_{}_{}.sock",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let server = ReplayServer::bind(service, &path, 7).expect("bind");
    let handle = std::thread::spawn(move || {
        server.serve().expect("serve");
    });
    for _ in 0..1_000 {
        if std::os::unix::net::UnixStream::connect(&path).is_ok() {
            return (path, handle);
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("fig_remote server never came up at {}", path.display());
}

fn stop_server(path: &Path, handle: std::thread::JoinHandle<()>) {
    RemoteClient::connect(path).expect("connect").shutdown().expect("shutdown");
    handle.join().expect("server thread");
}

/// W remote writers × `steps` appends at client batch `batch`;
/// returns (steps/s, wire bytes per Append RPC).
fn run_remote_append(writers: usize, batch: usize, steps: usize, capacity: usize) -> (f64, usize) {
    let service = mk_service(capacity);
    let (path, handle) = start_server(Arc::clone(&service));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..writers {
            let path = path.clone();
            s.spawn(move || {
                let mut writer = RemoteWriter::connect(&path, w as u64)
                    .expect("connect")
                    .with_batch(batch);
                for i in 0..steps {
                    assert!(!writer.throttled().expect("rpc"), "unlimited table throttled");
                    writer.append(mk_step(i)).expect("append");
                }
                assert_eq!(writer.flush().expect("flush"), 0);
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let inserts = service.table("replay").expect("table").stats_snapshot().inserts;
    assert_eq!(inserts, writers * steps, "appends lost on the wire");
    stop_server(&path, handle);
    // Representative Append payload: `batch` steps + framing (16 bytes
    // of magic/len/crc around the payload).
    let payload = Request::Append {
        actor_id: 0,
        seq: 0,
        dropped: 0,
        steps: (0..batch).map(mk_step).collect(),
    }
    .encode()
    .len();
    ((writers * steps) as f64 / secs, payload + 16)
}

/// The in-process upper bound: same loop through `TrajectoryWriter`s.
fn run_local_append(writers: usize, steps: usize, capacity: usize) -> f64 {
    let service = mk_service(capacity);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..writers {
            let service = Arc::clone(&service);
            s.spawn(move || {
                let mut writer = service.writer(w);
                let wr: &mut dyn ExperienceWriter = &mut writer;
                for i in 0..steps {
                    assert!(!wr.throttled().expect("local"), "unlimited table throttled");
                    wr.append(mk_step(i)).expect("append");
                }
            });
        }
    });
    (writers * steps) as f64 / t0.elapsed().as_secs_f64()
}

struct SampleResult {
    batches_per_sec: f64,
    /// Mean time the learner loop spent inside try_sample (the wait
    /// prefetch exists to hide).
    mean_wait_us: f64,
    mean_iter_us: f64,
}

/// One learner connection: `rounds` × (try_sample + update) at `batch`.
fn run_remote_sample(prefetch: bool, rounds: usize, batch: usize, capacity: usize) -> SampleResult {
    let service = mk_service(capacity);
    // Prefill past the batch size with stable priorities.
    let mut feeder = service.writer(0);
    for i in 0..(batch * 4).max(1_024) {
        feeder.append(mk_step(i));
    }
    let (path, handle) = start_server(Arc::clone(&service));

    let mut sampler = RemoteSampler::connect(&path, "replay", 11)
        .expect("sampler")
        .with_prefetch(prefetch);
    let mut rng = Rng::new(11);
    let mut out = SampleBatch::default();
    let tds: Vec<f32> = (0..batch).map(|j| (j % 7) as f32 * 0.3 + 0.1).collect();
    let mut wait = std::time::Duration::ZERO;
    let t0 = Instant::now();
    for _ in 0..rounds {
        let s0 = Instant::now();
        let outcome = sampler.try_sample(batch, &mut rng, &mut out).expect("sample");
        wait += s0.elapsed();
        assert_eq!(outcome, SampleOutcome::Sampled, "unlimited table stalled");
        sampler.update_priorities(&out.indices, &tds).expect("update");
    }
    let total = t0.elapsed();
    sampler.drain().expect("drain");
    drop(sampler);
    stop_server(&path, handle);
    SampleResult {
        batches_per_sec: rounds as f64 / total.as_secs_f64(),
        mean_wait_us: wait.as_secs_f64() * 1e6 / rounds as f64,
        mean_iter_us: total.as_secs_f64() * 1e6 / rounds as f64,
    }
}

struct MeshResult {
    batches_per_sec: f64,
    mass_rpcs: u64,
    sample_rpcs: u64,
}

/// One mesh learner over two replay servers: `rounds` two-level draws
/// (+ priority feedback) with the level-1 mass adverts either re-polled
/// every draw (`mass_ttl_ms` = 0) or cached for the given TTL. Returns
/// throughput plus the RPC counters the TTL cache exists to shrink.
fn run_mesh_sample(mass_ttl_ms: u64, rounds: usize, batch: usize, capacity: usize) -> MeshResult {
    let mut servers = Vec::new();
    let mut eps = Vec::new();
    for s in 0..2usize {
        let service = mk_service(capacity);
        let mut feeder = service.writer(s);
        for i in 0..(batch * 4).max(1_024) {
            feeder.append(mk_step(i));
        }
        drop(feeder);
        let (path, handle) = start_server(Arc::clone(&service));
        eps.push(Endpoint::Uds(path.clone()));
        servers.push((path, handle));
    }
    let mut sampler = MeshSampler::connect_default(&eps, 13, ConnectionPolicy::default())
        .expect("mesh sampler")
        .with_mass_ttl(Duration::from_millis(mass_ttl_ms));
    let mut rng = Rng::new(13);
    let mut out = SampleBatch::default();
    let tds: Vec<f32> = (0..batch).map(|j| (j % 7) as f32 * 0.3 + 0.1).collect();
    let t0 = Instant::now();
    for _ in 0..rounds {
        let outcome = sampler.try_sample(batch, &mut rng, &mut out).expect("mesh sample");
        assert_eq!(outcome, SampleOutcome::Sampled, "unlimited mesh stalled");
        sampler.update_priorities(&out.indices, &tds).expect("mesh update");
    }
    let total = t0.elapsed();
    let counters = sampler.counters();
    drop(sampler);
    for (path, handle) in servers {
        stop_server(&path, handle);
    }
    MeshResult {
        batches_per_sec: rounds as f64 / total.as_secs_f64(),
        mass_rpcs: counters.mass_rpcs,
        sample_rpcs: counters.sample_rpcs,
    }
}

fn main() -> anyhow::Result<()> {
    let a = Args::from_env()?;
    let smoke = a.flag("test");
    let default_writers: &[usize] = if smoke { &[2] } else { &[1, 2, 4] };
    let writer_list = a.usize_list("writers", default_writers)?;
    let default_batches: &[usize] = if smoke { &[1, 16] } else { &[1, 16, 64] };
    let batch_list = a.usize_list("batches", default_batches)?;
    let steps: usize = a.parse_or("steps", if smoke { 2_000 } else { 30_000 })?;
    let rounds: usize = a.parse_or("rounds", if smoke { 400 } else { 5_000 })?;
    let learner_batch: usize = a.parse_or("learner-batch", 64)?;
    let capacity: usize = a.parse_or("capacity", 65_536)?;

    println!(
        "Remote replay data path (real Unix socket): append batching x writers, \
         sample prefetch on/off; {steps} steps/writer, {rounds} sample rounds, \
         learner batch {learner_batch}{}\n",
        if smoke { " [smoke]" } else { "" },
    );

    // --- Append side ---------------------------------------------------
    let mut report = Report::new(&[
        "path", "writers", "batch", "steps/s", "bytes/RPC", "vs batch=1", "vs local",
    ]);
    // (writers, batch) -> steps/s for the JSON + verdicts.
    let mut append_rows: Vec<(usize, usize, f64, usize, f64)> = Vec::new();
    let mut local_rows: Vec<(usize, f64)> = Vec::new();
    for &w in &writer_list {
        let local = run_local_append(w, steps, capacity);
        local_rows.push((w, local));
        // Measure every batch size first, then normalize against the
        // batch-1 row wherever it sits in the sweep (1.0 when the
        // sweep omits batch 1).
        let measured: Vec<(usize, f64, usize)> = batch_list
            .iter()
            .map(|&b| {
                let (rate, bytes) = run_remote_append(w, b, steps, capacity);
                (b, rate, bytes)
            })
            .collect();
        let base1 = measured.iter().find(|r| r.0 == 1).map(|r| r.1);
        for (b, rate, bytes) in measured {
            let vs1 = rate / base1.unwrap_or(rate).max(1e-9);
            append_rows.push((w, b, rate, bytes, vs1));
            report.row(vec![
                "remote".into(),
                w.to_string(),
                b.to_string(),
                format!("{rate:.0}"),
                bytes.to_string(),
                format!("{vs1:.2}x"),
                format!("{:.2}x", rate / local.max(1e-9)),
            ]);
        }
        report.row(vec![
            "in-process".into(),
            w.to_string(),
            "-".into(),
            format!("{local:.0}"),
            "-".into(),
            "-".into(),
            "1.00x".into(),
        ]);
    }
    report.print();

    // --- Sample side ---------------------------------------------------
    let off = run_remote_sample(false, rounds, learner_batch, capacity);
    let on = run_remote_sample(true, rounds, learner_batch, capacity);
    let hidden = 1.0 - on.mean_wait_us / off.mean_wait_us.max(1e-9);
    println!("\nsample path (batch {learner_batch}, {rounds} rounds):");
    let mut sreport = Report::new(&["prefetch", "batches/s", "sample wait", "iter time"]);
    for (name, r) in [("off", &off), ("on", &on)] {
        sreport.row(vec![
            name.into(),
            format!("{:.0}", r.batches_per_sec),
            format!("{:.1} µs", r.mean_wait_us),
            format!("{:.1} µs", r.mean_iter_us),
        ]);
    }
    sreport.print();

    // --- Mesh sample side ----------------------------------------------
    let mesh_off = run_mesh_sample(0, rounds, learner_batch, capacity);
    let mesh_on = run_mesh_sample(5, rounds, learner_batch, capacity);
    println!("\nmesh sample path (2 servers, batch {learner_batch}, {rounds} rounds):");
    let mut mreport =
        Report::new(&["mass ttl", "batches/s", "mass RPCs", "sample RPCs", "RPCs/batch"]);
    for (name, r) in [("0 (every draw)", &mesh_off), ("5 ms", &mesh_on)] {
        mreport.row(vec![
            name.into(),
            format!("{:.0}", r.batches_per_sec),
            r.mass_rpcs.to_string(),
            r.sample_rpcs.to_string(),
            format!("{:.2}", (r.mass_rpcs + r.sample_rpcs) as f64 / rounds as f64),
        ]);
    }
    mreport.print();

    // --- Verdicts ------------------------------------------------------
    // Smallest batch-16 speedup across writer counts (5x target); the
    // batch list may omit 16 in a custom sweep, then it's skipped.
    let speedup16 = writer_list
        .iter()
        .filter_map(|&w| {
            let b1 = append_rows.iter().find(|r| r.0 == w && r.1 == 1)?.2;
            let b16 = append_rows.iter().find(|r| r.0 == w && r.1 == 16)?.2;
            Some(b16 / b1.max(1e-9))
        })
        .fold(f64::INFINITY, f64::min);
    if speedup16.is_finite() {
        println!(
            "\nverdict: append batch=16 vs batch=1, worst over writer counts = \
             {speedup16:.2}x — target >= 5x [{}]",
            if speedup16 >= 5.0 { "OK" } else { "MISS" }
        );
    }
    println!(
        "verdict: prefetch hides {:.0}% of the per-batch sample wait \
         ({:.1} µs -> {:.1} µs) — target >= 50% [{}]",
        hidden * 100.0,
        off.mean_wait_us,
        on.mean_wait_us,
        if hidden >= 0.5 { "OK" } else { "MISS" }
    );

    if smoke {
        // The deterministic part is the CI gate (data integrity across
        // the wire, asserted inside the runs); wall-clock verdicts stay
        // advisory on shared runners.
        println!("\nsmoke OK: all configurations moved every step and batch");
    }

    // --- Machine-readable output ---------------------------------------
    if let Some(path) = a.get("json") {
        let mut j = String::from("{\n  \"bench\": \"fig_remote\",\n");
        j.push_str(&format!(
            "  \"config\": {{\"steps\": {steps}, \"rounds\": {rounds}, \
             \"learner_batch\": {learner_batch}, \"capacity\": {capacity}, \
             \"smoke\": {smoke}}},\n"
        ));
        j.push_str("  \"append\": [\n");
        for (i, (w, b, rate, bytes, vs1)) in append_rows.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"writers\": {w}, \"remote_batch\": {b}, \"steps_per_sec\": {rate:.1}, \
                 \"bytes_per_rpc\": {bytes}, \"speedup_vs_batch1\": {vs1:.3}}}{}\n",
                if i + 1 < append_rows.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n  \"in_process\": [\n");
        for (i, (w, rate)) in local_rows.iter().enumerate() {
            j.push_str(&format!(
                "    {{\"writers\": {w}, \"steps_per_sec\": {rate:.1}}}{}\n",
                if i + 1 < local_rows.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n  \"sample\": [\n");
        for (i, (name, r)) in [("off", &off), ("on", &on)].iter().enumerate() {
            j.push_str(&format!(
                "    {{\"prefetch\": \"{name}\", \"batches_per_sec\": {:.1}, \
                 \"mean_sample_wait_us\": {:.2}, \"mean_iter_us\": {:.2}}}{}\n",
                r.batches_per_sec,
                r.mean_wait_us,
                r.mean_iter_us,
                if i == 0 { "," } else { "" }
            ));
        }
        j.push_str("  ],\n  \"mesh\": [\n");
        for (i, (ttl, r)) in [(0u64, &mesh_off), (5u64, &mesh_on)].iter().enumerate() {
            j.push_str(&format!(
                "    {{\"mass_ttl_ms\": {ttl}, \"batches_per_sec\": {:.1}, \
                 \"mass_rpcs\": {}, \"sample_rpcs\": {}, \"rpcs_per_batch\": {:.3}}}{}\n",
                r.batches_per_sec,
                r.mass_rpcs,
                r.sample_rpcs,
                (r.mass_rpcs + r.sample_rpcs) as f64 / rounds as f64,
                if i == 0 { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "  ],\n  \"verdicts\": {{\"append_speedup_batch16_worst\": {}, \
             \"append_target\": 5.0, \"sample_wait_hidden_frac\": {hidden:.3}, \
             \"sample_target\": 0.5}},\n",
            if speedup16.is_finite() { format!("{speedup16:.3}") } else { "null".into() },
        ));
        j.push_str(
            "  \"gate\": {\"append_speedup_batch16_worst\": {\"floor\": 1.0, \"tolerance\": 0.5}, \
             \"sample_wait_hidden_frac\": {\"floor\": 0.0, \"tolerance\": 0.5}}\n}\n",
        );
        std::fs::write(path, j)?;
        eprintln!("[fig_remote] results written to {path}");
    }
    Ok(())
}
