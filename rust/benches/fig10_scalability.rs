//! Fig 10 — scalability of the framework vs number of CPU cores,
//! normalized to the sequential (1-core) implementation, for DQN, DDPG
//! and SAC.
//!
//! Paper's shape: near-linear below ~4 cores, saturating above ~6 when
//! the GPU (here: the serialized accelerator resource in the DES)
//! becomes the bottleneck. Projection uses the DES with representative
//! measured costs; a real-thread column at 1–2 workers grounds the model
//! on this host.

use pal_rl::coordinator::{train, TrainConfig};
use pal_rl::dse::CostProfile;
use pal_rl::util::bench::Table;

fn real_pair_throughput(algo: &str, env: &str, actors: usize, learners: usize)
    -> anyhow::Result<f64>
{
    let mut cfg = TrainConfig::new(algo, env);
    cfg.total_env_steps = 1_500;
    cfg.warmup_steps = 200;
    cfg.update_interval = 2.0;
    cfg.actors = actors;
    cfg.learners = learners;
    cfg.actor_lead = 0;
    cfg.seed = 13;
    Ok(train(&cfg)?.env_steps_per_sec)
}

fn main() -> anyhow::Result<()> {
    // `--test` = CI smoke: DES projection only (real-thread grounding
    // needs artifacts and wall clock).
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("Fig 10 — scalability vs CPU cores (normalized to 1 core)\n");

    for algo in ["dqn", "ddpg", "sac"] {
        let env = if algo == "dqn" { "CartPole-v1" } else { "Pendulum-v1" };
        let mut profile = CostProfile::representative(algo, env);
        profile.serialized_accel = true; // paper testbed: one GPU
        profile.accel_slots = 4;         // ...with a few batches in flight
        let mut t = Table::new(&["cores", "actors+learners", "steps/s (DES)", "speedup"]);
        let mut base = 0.0f64;
        for cores in 1..=8usize {
            // Best balanced split at each core count (ratio 1): the
            // training throughput the paced pipeline can sustain.
            let (a, l, tput) = profile.best_balanced(cores, 1.0);
            if cores == 1 {
                base = tput;
            }
            t.row(vec![
                cores.to_string(),
                format!("{a}+{l}"),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base.max(1e-9)),
            ]);
        }
        println!("{algo} ({env}):");
        t.print();
        println!();
    }

    // Ground truth on this host: 1 vs 2 worker pairs (time-shared on one
    // physical core; validates the pipeline, not parallel speedup).
    if !test_mode && std::path::Path::new("artifacts/manifest.json").exists() {
        let one = real_pair_throughput("dqn", "CartPole-v1", 1, 1)?;
        let two = real_pair_throughput("dqn", "CartPole-v1", 2, 2)?;
        println!(
            "real-thread grounding (1-core host, time-shared): 1+1 workers \
             {one:.0} steps/s, 2+2 workers {two:.0} steps/s"
        );
    }
    println!(
        "\npaper's shape: linear scaling below 4 cores, saturation above 6\n\
         as the accelerator (GPU) becomes the bottleneck."
    );
    Ok(())
}
