//! Fig 1 — motivation: training cost grows with the size of the state
//! space. The paper plots wall-clock training time of Mujoco / Atari /
//! Go; we reproduce the trend across our environments: wall-clock per
//! 10k random-policy environment steps plus the measured per-step cost,
//! ordered by observation dimensionality.

use pal_rl::env::{make_env, ActionSpace, ENV_NAMES};
use pal_rl::util::bench::{fmt_ns, Table};
use pal_rl::util::rng::Rng;
use std::time::Instant;

fn main() {
    // `--test` = CI smoke: tiny step budget, same code paths.
    let test_mode = std::env::args().any(|a| a == "--test");
    println!("Fig 1 — per-step simulator cost vs state-space size\n");
    let mut rows: Vec<(usize, String, f64)> = Vec::new();

    for name in ENV_NAMES {
        let mut env = make_env(name).unwrap();
        let spec = env.spec().clone();
        let mut rng = Rng::new(1);
        let mut obs = env.reset(&mut rng);
        let steps = if test_mode { 500usize } else { 10_000usize };
        let t0 = Instant::now();
        for _ in 0..steps {
            let action = match &spec.action_space {
                ActionSpace::Discrete(n) => vec![rng.below_usize(*n) as f32],
                ActionSpace::Continuous { dim, low, high } => {
                    (0..*dim).map(|_| rng.range_f32(*low, *high)).collect()
                }
            };
            let s = env.step(&action, &mut rng);
            if s.done || s.truncated {
                obs = env.reset(&mut rng);
            } else {
                obs = s.obs;
            }
        }
        std::hint::black_box(&obs);
        let per_step = t0.elapsed().as_nanos() as f64 / steps as f64;
        rows.push((spec.obs_dim, spec.name.to_string(), per_step));
    }
    rows.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));

    let mut t = Table::new(&["obs_dim", "environment", "ns/step", "10k steps"]);
    for (dim, name, per_step) in &rows {
        t.row(vec![
            dim.to_string(),
            name.clone(),
            format!("{per_step:.0}"),
            fmt_ns(per_step * 10_000.0),
        ]);
    }
    t.print();
    println!(
        "\npaper's trend: bigger state spaces (Mujoco < Atari < Go) need both\n\
         costlier simulators and more samples — compounding training time.\n\
         The same ordering appears across our environments above."
    );
}
