//! Table I — resource utilization of the replay buffer operations,
//! regenerated from the lock instrumentation: which locks/storage each
//! operation touches, with measured acquisition counts and hold times.

use pal_rl::replay::{
    PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition,
};
use pal_rl::util::bench::{bench_fn, fmt_ns, header, Table};
use pal_rl::util::rng::Rng;

fn tr(v: f32) -> Transition {
    Transition {
        obs: vec![v; 8],
        action: vec![v; 2],
        next_obs: vec![v; 8],
        reward: v,
        done: false,
    }
}

fn fresh(n: usize) -> PrioritizedReplay {
    let buf = PrioritizedReplay::new(PrioritizedConfig {
        capacity: n,
        obs_dim: 8,
        act_dim: 2,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    });
    for i in 0..n {
        buf.insert(&tr(i as f32));
    }
    buf
}

fn main() {
    let n = 100_000usize;

    // ---- Table I: locks touched per operation (from instrumentation).
    println!("Table I — resource utilization of various operations (measured)\n");
    let probe = |f: &dyn Fn(&PrioritizedReplay)| {
        let b = fresh(1_024);
        b.stats.enable_timing();
        let before = b.stats.snapshot();
        f(&b);
        let after = b.stats.snapshot();
        (
            after.global_acquisitions - before.global_acquisitions,
            after.leaf_acquisitions - before.leaf_acquisitions,
            after.storage_copy_ns > before.storage_copy_ns,
        )
    };
    
    let (g_i, l_i, s_i) = probe(&|b| b.insert(&tr(0.0)));
    let (g_s, l_s, _) = probe(&|b| {
        let mut out = SampleBatch::default();
        b.sample(32, &mut Rng::new(1), &mut out);
    });
    let (g_r, l_r, _) = probe(&|b| {
        b.get_priority(5);
    });
    let (g_u, l_u, _) = probe(&|b| b.update_priorities(&[777], &[0.5]));

    let mut t = Table::new(&["operation", "global_tree_lock", "last_level_lock", "storage"]);
    t.row(vec!["insertion".into(), format!("{g_i} acq"), format!("{l_i} acq"),
               if s_i { "modify (no lock)".into() } else { "-".into() }]);
    t.row(vec!["sampling (batch 32)".into(), format!("{g_s} acq"), format!("{l_s} acq"),
               "read (no lock)".into()]);
    t.row(vec!["priority retrieval".into(), format!("{g_r} acq"), format!("{l_r} acq"),
               "-".into()]);
    t.row(vec!["priority update".into(), format!("{g_u} acq"), format!("{l_u} acq"),
               "-".into()]);
    t.print();

    // ---- micro-benchmarks of each op at N = 100k.
    header(&format!("buffer op latency, N = {n}, K = 64"));
    let buf = fresh(n);
    buf.stats.enable_timing();
    let mut i = 0usize;
    println!("{}", bench_fn("insert (lazy writing)", 300, || {
        buf.insert(&tr(i as f32));
        i += 1;
    }));
    let mut rng = Rng::new(2);
    let mut out = SampleBatch::with_capacity(32, 8, 2);
    println!("{}", bench_fn("sample batch=32", 300, || {
        buf.sample(32, &mut rng, &mut out);
    }));
    println!("{}", bench_fn("priority retrieval", 200, || {
        std::hint::black_box(buf.get_priority(12345));
    }));
    let idx: Vec<usize> = (0..32).map(|_| rng.below_usize(n)).collect();
    let tds = vec![0.4f32; 32];
    println!("{}", bench_fn("priority update batch=32", 300, || {
        buf.update_priorities(&idx, &tds);
    }));
    println!("{}", bench_fn("total priority (root read)", 100, || {
        std::hint::black_box(buf.total_priority());
    }));

    // Hold-time accounting accumulated over the benches above.
    let s = buf.stats.snapshot();
    println!(
        "\nlock hold times: global {} avg over {} acq; leaf {} avg over {} acq",
        fmt_ns((s.global_held_ns / s.global_acquisitions.max(1)) as f64),
        s.global_acquisitions,
        fmt_ns((s.leaf_held_ns / s.leaf_acquisitions.max(1)) as f64),
        s.leaf_acquisitions,
    );
    println!(
        "lock wait times: global {} avg; leaf {} avg (time spent blocked \
         before each acquisition, separate from hold)",
        fmt_ns((s.global_wait_ns / s.global_acquisitions.max(1)) as f64),
        fmt_ns((s.leaf_wait_ns / s.leaf_acquisitions.max(1)) as f64),
    );
    println!(
        "storage copy time (outside locks, lazy writing): {} total",
        fmt_ns(s.storage_copy_ns as f64)
    );
}
