//! §Perf probe — not a paper figure. Measures the L3/RT hot paths:
//! act-graph execution (actor inner loop), learn-graph execution
//! (learner inner loop), parameter-server sync, and batch assembly.
//! Used to drive the EXPERIMENTS.md §Perf iteration log.

use pal_rl::agent::{Agent, Exploration};
use pal_rl::replay::{PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition};
use pal_rl::runtime::{Manifest, Runtime};
use pal_rl::util::bench::{bench_fn, header};
use pal_rl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load("artifacts")?;
    let info = manifest.get("dqn_CartPole-v1")?.clone();
    let rt = Runtime::cpu()?;
    let model = rt.load_model(&info)?;
    let mut agent = Agent::new(model, Exploration::default())?;
    let params = info.load_initial_params()?;
    let mut rng = Rng::new(1);
    let obs = vec![0.1f32; info.obs_dim];

    header("actor hot path (dqn @ CartPole, B=1)");
    let mut step = 0usize;
    println!("{}", bench_fn("agent.act (greedy, uncached)", 1500, || {
        step += 1;
        agent.act(&params, &obs, usize::MAX, false, &mut rng).unwrap();
    }));
    println!("{}", bench_fn("agent.act_cached (device-resident params)", 1500, || {
        step += 1;
        agent.act_cached(&params, 1, &obs, usize::MAX, false, &mut rng).unwrap();
    }));

    header("learner hot path (dqn @ CartPole, B=64)");
    let buf = PrioritizedReplay::new(PrioritizedConfig {
        capacity: 10_000,
        obs_dim: info.obs_dim,
        act_dim: info.flat_act_dim,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    });
    for i in 0..5_000 {
        buf.insert(&Transition {
            obs: vec![i as f32 * 0.01; info.obs_dim],
            action: vec![(i % 2) as f32],
            next_obs: vec![i as f32 * 0.01 + 0.1; info.obs_dim],
            reward: 1.0,
            done: false,
        });
    }
    let mut batch = SampleBatch::with_capacity(64, info.obs_dim, info.flat_act_dim);
    buf.sample(info.batch_size, &mut rng, &mut batch);
    let targets = params.clone();
    println!("{}", bench_fn("agent.learn (grads+|TD|+loss)", 2500, || {
        agent.learn(&params, &targets, &batch, &mut rng).unwrap();
    }));
    println!("{}", bench_fn("buffer.sample batch=64", 400, || {
        buf.sample(info.batch_size, &mut rng, &mut batch);
    }));

    header("parameter server");
    let server = pal_rl::params::ParameterServer::new(
        params.clone(),
        pal_rl::params::AdamConfig::default(),
        pal_rl::params::TargetSync::Polyak { tau: 0.005 },
        1,
    );
    let grads = vec![0.01f32; params.len()];
    println!("{}", bench_fn("push_gradient (full net, Adam)", 400, || {
        server.push_gradient(0, grads.len(), &grads);
    }));
    let mut snap = Vec::new();
    let mut v = 0u64;
    println!("{}", bench_fn("sync_online (stale)", 300, || {
        v = 0;
        v = server.sync_online(&mut snap, v);
    }));
    Ok(())
}
