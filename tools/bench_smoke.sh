#!/usr/bin/env bash
# CI bench smoke: run EVERY fig* bench (plus the ablation bench, which
# the glob misses) in its `--test` configuration so a bench that stops
# compiling or starts crashing fails the build instead of silently
# rotting. The list is discovered from the tree, so new fig* benches
# are swept automatically. fig_remote is skipped here:
# tools/bench_remote.sh runs the same --test sweep (and writes
# BENCH_remote.json) as its own CI step — running the real-socket sweep
# twice per push buys nothing.
#
# Benches with a --json mode also write their smoke-sized BENCH_*.json
# artifact at the repo root, so the compare step and the artifact trail
# cover every fig bench, not just fig_remote.
set -euo pipefail
cd "$(dirname "$0")/.."

# bench name -> committed artifact it refreshes (empty = no JSON mode).
json_out() {
    case "$1" in
        fig9_sumtree)  echo "BENCH_sumtree.json" ;;
        fig_service)   echo "BENCH_service.json" ;;
        fig13_sharding) echo "BENCH_sharding.json" ;;
        *) echo "" ;;
    esac
}

status=0
for src in rust/benches/fig*.rs rust/benches/ablation_lazy_writing.rs; do
    bench="$(basename "$src" .rs)"
    if [ "$bench" = "fig_remote" ]; then
        continue
    fi
    out="$(json_out "$bench")"
    args=(--test)
    if [ -n "$out" ]; then
        # Absolute path: cargo runs bench binaries with cwd set to the
        # package root (rust/), not the workspace root this script
        # cd'd to.
        args+=(--json "$PWD/$out")
    fi
    echo "::group::bench $bench -- ${args[*]}"
    if ! cargo bench --bench "$bench" -- "${args[@]}"; then
        echo "FAILED: $bench"
        status=1
    fi
    echo "::endgroup::"
done
exit "$status"
