#!/usr/bin/env bash
# CI bench smoke: run EVERY fig* bench in its `--test` configuration so
# a bench that stops compiling or starts crashing fails the build
# instead of silently rotting. The list is discovered from the tree, so
# new fig* benches are swept automatically. fig_remote is skipped here:
# tools/bench_remote.sh runs the same --test sweep (and writes
# BENCH_remote.json) as its own CI step — running the real-socket sweep
# twice per push buys nothing.
set -euo pipefail
cd "$(dirname "$0")/.."

status=0
for src in rust/benches/fig*.rs; do
    bench="$(basename "$src" .rs)"
    if [ "$bench" = "fig_remote" ]; then
        continue
    fi
    echo "::group::bench $bench --test"
    if ! cargo bench --bench "$bench" -- --test; then
        echo "FAILED: $bench"
        status=1
    fi
    echo "::endgroup::"
done
exit "$status"
