#!/usr/bin/env python3
"""Compare a fresh BENCH_*.json artifact against its committed baseline.

Wall-clock absolutes are meaningless across runners, so every gate is on
RATIOS: each bench artifact carries a `verdicts` map (name -> ratio) and
a `gate` map (name -> {"floor": f, "tolerance": t}) describing how far a
fresh ratio may dip below the committed baseline's before the step
fails. `floor` is the hard minimum asserting the optimization never
makes things WORSE regardless of baseline drift; `tolerance` (falling
back to --tolerance when a gate omits it) scales the baseline into the
required value:

    need = max(floor, tolerance * baseline_ratio)

A shared-runner hiccup cannot fail CI under a 0.5 tolerance, but a real
regression (batching disabled, sharding broken, descent pessimized)
still does. Verdict keys present in only one of the two files are
reported and skipped, so sweeps can grow new verdicts without breaking
the compare against an older baseline. One script gates every artifact:
BENCH_remote.json, BENCH_sumtree.json, BENCH_service.json,
BENCH_sharding.json.

Usage: tools/bench_compare.py FRESH BASELINE [--tolerance 0.5]
"""

import argparse
import json
import sys

# Gates for artifacts predating the embedded `gate` map (the PR-7-era
# BENCH_remote.json layout, where floors lived in this script).
LEGACY_GATES = {
    "fig_remote": {
        "append_speedup_batch16_worst": {"floor": 1.0},
        "sample_wait_hidden_frac": {"floor": 0.0},
    },
}


def load(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data.get("bench"), str):
        sys.exit(f"{path}: missing `bench` name")
    if not isinstance(data.get("verdicts"), dict):
        sys.exit(f"{path}: missing `verdicts` map")
    return data


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("fresh", help="just-produced BENCH_*.json")
    ap.add_argument("baseline", help="committed baseline to diff against")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.5,
        help="default fraction of the baseline a fresh ratio must reach "
        "when a gate entry has no tolerance of its own (default 0.5)",
    )
    args = ap.parse_args()
    fresh, base = load(args.fresh), load(args.baseline)

    if fresh["bench"] != base["bench"]:
        sys.exit(
            f"bench mismatch: fresh is {fresh['bench']!r}, "
            f"baseline is {base['bench']!r}"
        )

    gates = fresh.get("gate") or base.get("gate") or LEGACY_GATES.get(fresh["bench"])
    if not gates:
        sys.exit(f"{args.fresh}: no `gate` map and no legacy gate for {fresh['bench']!r}")

    failures = []
    fv, bv = fresh["verdicts"], base["verdicts"]
    for name, spec in sorted(gates.items()):
        f, b = fv.get(name), bv.get(name)
        if f is None or b is None:
            # A custom/smoke sweep may omit a verdict; skip, don't fail.
            print(f"{name}: missing (fresh {f}, baseline {b}) -- skipped")
            continue
        floor = float(spec.get("floor", 0.0))
        tol = float(spec.get("tolerance", args.tolerance))
        need = max(floor, tol * b)
        verdict = "OK" if f >= need else "REGRESSION"
        print(f"{name}: fresh {f:.3f} vs baseline {b:.3f} (need >= {need:.3f}) [{verdict}]")
        if f < need:
            failures.append(name)

    if fresh.get("config") != base.get("config"):
        print(
            f"note: sweep configs differ (fresh {fresh.get('config')} vs "
            f"baseline {base.get('config')}) -- ratio gates still apply"
        )

    if failures:
        sys.exit("bench compare FAILED: " + ", ".join(failures))
    print(f"bench compare OK ({fresh['bench']}: {len(gates)} gate(s))")


if __name__ == "__main__":
    main()
