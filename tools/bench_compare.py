#!/usr/bin/env python3
"""Compare a fresh BENCH_remote.json against the committed baseline.

Wall-clock absolutes are meaningless across runners, so the gate is on
RATIOS — the append batch-16 speedup over batch-1, and the fraction of
the per-batch sample wait hidden by prefetch — with a wide tolerance:
a fresh ratio may dip to half the baseline's before the step fails.
Hard floors only assert the optimizations never make things WORSE
(speedup >= 1.0, hidden fraction >= 0.0), so a shared-runner hiccup
cannot fail CI but a real regression (batching or prefetch effectively
disabled) still does.

Usage: tools/bench_compare.py FRESH BASELINE [--tolerance 0.5]
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        data = json.load(f)
    if data.get("bench") != "fig_remote":
        sys.exit(f"{path}: not a fig_remote result (bench = {data.get('bench')!r})")
    return data


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="just-produced BENCH_remote.json")
    ap.add_argument("baseline", help="committed baseline to diff against")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.5,
        help="fresh ratio must reach this fraction of the baseline's (default 0.5)",
    )
    args = ap.parse_args()
    fresh, base = load(args.fresh), load(args.baseline)

    failures = []

    def gate(name, f, b, floor):
        if f is None or b is None:
            # A custom sweep may omit batch 16; the ratio is then null.
            print(f"{name}: missing (fresh {f}, baseline {b}) -- skipped")
            return
        need = max(floor, args.tolerance * b)
        verdict = "OK" if f >= need else "REGRESSION"
        print(f"{name}: fresh {f:.3f} vs baseline {b:.3f} (need >= {need:.3f}) [{verdict}]")
        if f < need:
            failures.append(name)

    fv, bv = fresh.get("verdicts", {}), base.get("verdicts", {})
    gate(
        "append_speedup_batch16_worst",
        fv.get("append_speedup_batch16_worst"),
        bv.get("append_speedup_batch16_worst"),
        1.0,
    )
    gate(
        "sample_wait_hidden_frac",
        fv.get("sample_wait_hidden_frac"),
        bv.get("sample_wait_hidden_frac"),
        0.0,
    )

    if fresh.get("config") != base.get("config"):
        print(
            f"note: sweep configs differ (fresh {fresh.get('config')} vs "
            f"baseline {base.get('config')}) -- ratio gates still apply"
        )

    if failures:
        sys.exit("bench compare FAILED: " + ", ".join(failures))
    print("bench compare OK")


if __name__ == "__main__":
    main()
