#!/usr/bin/env bash
# Remote data-path perf baseline: run the fig_remote sweep (batched
# appends x writers, sample prefetch on/off over a real Unix socket)
# and write machine-readable BENCH_remote.json at the repo root, so
# every future PR that touches the remote path has a number to diff
# against. A snapshot is committed at the repo root; CI re-runs the
# smoke sweep and gates the ratio metrics against the committed copy
# via tools/bench_compare.py (wide tolerance — see that script).
#
# Usage: tools/bench_remote.sh [--smoke] [extra fig_remote flags...]
#   --smoke   small CI-sized sweep (still writes the JSON)
set -euo pipefail
cd "$(dirname "$0")/.."

out="BENCH_remote.json"
extra=()
if [ "${1:-}" = "--smoke" ]; then
    shift
    extra+=(--test)
fi

# Absolute output path: cargo runs bench binaries with cwd set to the
# package root (rust/), not the workspace root this script cd'd to.
cargo bench --bench fig_remote -- --json "$PWD/$out" "${extra[@]}" "$@"

# The JSON must exist and parse as the gate for the step itself.
python3 - "$out" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    data = json.load(f)
assert data["bench"] == "fig_remote"
assert data["append"], "no append rows recorded"
assert data["sample"], "no sample rows recorded"
v = data["verdicts"]
print(
    f"BENCH_remote.json OK: batch16 speedup "
    f"{v['append_speedup_batch16_worst']}x (target {v['append_target']}x), "
    f"prefetch hides {100 * v['sample_wait_hidden_frac']:.0f}% "
    f"(target {100 * v['sample_target']:.0f}%)"
)
EOF
