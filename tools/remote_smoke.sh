#!/usr/bin/env bash
# CI remote replay smoke: start `pal serve` on a Unix socket, then run
# `pal remote-smoke` against it — a deterministic collect/sample phase
# whose checkpoint must be BYTE-identical to an in-process twin, a
# concurrent multi-client soak with exact sample-to-insert accounting
# over the Stats RPC, and a clean Shutdown RPC. The script then asserts
# the serving process exited 0 and wrote its --save-state replay state.
#
# A second phase starts TWO `pal serve --tcp` servers on ephemeral
# loopback ports and runs `pal mesh-smoke` across them: affinity
# appends, lockstep two-level sampling, chunked per-server checkpoints
# byte-identical to in-process twins, and exact per-server Stats
# accounting, ending in a Shutdown RPC to each server.
set -euo pipefail
cd "$(dirname "$0")/.."

dir="${1:-$(mktemp -d)}"
socket="$dir/replay.sock"
state_dir="$dir/state"

cargo build --release --bin pal

# Server and smoke client must agree on the table layout: remote-smoke
# drives the state-smoke shape (sharded prioritized `replay` 1step
# under a σ=1 ratio limiter + free-running `aux` nstep:3, warmup 64).
./target/release/pal serve \
  --socket "$socket" \
  --capacity 4096 --shards 4 --warmup 64 --rate-limit 1.0 \
  --tables "replay=1step,aux=nstep:3" \
  --obs-dim 4 --act-dim 2 \
  --save-state "$state_dir" &
server_pid=$!

cleanup() {
  kill "$server_pid" 2>/dev/null || true
}
trap cleanup EXIT

# Wait for the socket to come up.
for _ in $(seq 1 100); do
  [ -S "$socket" ] && break
  sleep 0.1
done
[ -S "$socket" ] || { echo "server socket never appeared" >&2; exit 1; }

./target/release/pal remote-smoke --socket "$socket" --capacity 4096 --shards 4

# The Shutdown RPC must end the serving process cleanly...
wait "$server_pid"
trap - EXIT

# ...and its clean-shutdown state save must exist.
[ -f "$state_dir/replay_state.bin" ] || {
  echo "server did not write replay_state.bin on shutdown" >&2
  exit 1
}

# --- Cross-host mesh phase: two TCP servers, one logical table. ---
# Flags must mirror mesh-smoke's in-process twin layout (capacity /
# shards / warmup 64 / unlimited limiter / 1step+nstep:3 tables).
serve_mesh_member() {
  ./target/release/pal serve \
    --tcp 127.0.0.1:0 \
    --capacity 4096 --shards 4 --warmup 64 --rate-limit unlimited \
    --tables "replay=1step,aux=nstep:3" \
    --obs-dim 4 --act-dim 2 \
    2>"$1" &
}

# Each server binds an ephemeral port and prints the RESOLVED endpoint
# on its `listening on` stderr line; parse those to build the mesh.
endpoint_of() {
  local log="$1" ep=""
  for _ in $(seq 1 100); do
    ep=$(sed -n 's#.*listening on \(tcp://[0-9.]*:[0-9]*\).*#\1#p' "$log" | head -n 1)
    [ -n "$ep" ] && break
    sleep 0.1
  done
  [ -n "$ep" ] || { echo "mesh server ($log) never reported its endpoint" >&2; return 1; }
  echo "$ep"
}

serve_mesh_member "$dir/mesh1.log"
mesh_pid1=$!
serve_mesh_member "$dir/mesh2.log"
mesh_pid2=$!

cleanup_mesh() {
  kill "$mesh_pid1" "$mesh_pid2" 2>/dev/null || true
}
trap cleanup_mesh EXIT

ep1=$(endpoint_of "$dir/mesh1.log")
ep2=$(endpoint_of "$dir/mesh2.log")

./target/release/pal mesh-smoke --endpoints "$ep1,$ep2" --capacity 4096 --shards 4

# mesh-smoke ends with a Shutdown RPC to every server.
wait "$mesh_pid1"
wait "$mesh_pid2"
trap - EXIT

echo "remote replay smoke OK ($dir): UDS phase + 2-server TCP mesh ($ep1 $ep2)"
