#!/usr/bin/env bash
# CI remote replay smoke: start `pal serve` on a Unix socket, then run
# `pal remote-smoke` against it — a deterministic collect/sample phase
# whose checkpoint must be BYTE-identical to an in-process twin, a
# concurrent multi-client soak with exact sample-to-insert accounting
# over the Stats RPC, and a clean Shutdown RPC. The script then asserts
# the serving process exited 0 and wrote its --save-state replay state.
#
# A second phase starts TWO `pal serve --tcp` servers on ephemeral
# loopback ports and runs `pal mesh-smoke` across them: affinity
# appends, lockstep two-level sampling, chunked per-server checkpoints
# byte-identical to in-process twins, and exact per-server Stats
# accounting, ending in a Shutdown RPC to each server.
#
# A third phase starts one multi-tenant server — per-writer budgets, a
# writers-per-table cap, LIFO eviction on its hot table, and the
# COMMITTED legacy PALSTAT1 checkpoint restored at boot (the blocking
# v1 forward-compat gate: serve exits nonzero if the old file stops
# loading) — and runs `pal tenant-smoke` against it: two writers with
# disjoint table ACLs plus a third bouncing off the writer cap, with
# exact per-tenant insert/eviction/sample-count accounting over Stats.
set -euo pipefail
cd "$(dirname "$0")/.."

dir="${1:-$(mktemp -d)}"
socket="$dir/replay.sock"
state_dir="$dir/state"

cargo build --release --bin pal

# Server and smoke client must agree on the table layout: remote-smoke
# drives the state-smoke shape (sharded prioritized `replay` 1step
# under a σ=1 ratio limiter + free-running `aux` nstep:3, warmup 64).
./target/release/pal serve \
  --socket "$socket" \
  --capacity 4096 --shards 4 --warmup 64 --rate-limit 1.0 \
  --tables "replay=1step,aux=nstep:3" \
  --obs-dim 4 --act-dim 2 \
  --save-state "$state_dir" &
server_pid=$!

cleanup() {
  kill "$server_pid" 2>/dev/null || true
}
trap cleanup EXIT

# Wait for the socket to come up.
for _ in $(seq 1 100); do
  [ -S "$socket" ] && break
  sleep 0.1
done
[ -S "$socket" ] || { echo "server socket never appeared" >&2; exit 1; }

./target/release/pal remote-smoke --socket "$socket" --capacity 4096 --shards 4

# The Shutdown RPC must end the serving process cleanly...
wait "$server_pid"
trap - EXIT

# ...and its clean-shutdown state save must exist.
[ -f "$state_dir/replay_state.bin" ] || {
  echo "server did not write replay_state.bin on shutdown" >&2
  exit 1
}

# --- Cross-host mesh phase: two TCP servers, one logical table. ---
# Flags must mirror mesh-smoke's in-process twin layout (capacity /
# shards / warmup 64 / unlimited limiter / 1step+nstep:3 tables).
serve_mesh_member() {
  ./target/release/pal serve \
    --tcp 127.0.0.1:0 \
    --capacity 4096 --shards 4 --warmup 64 --rate-limit unlimited \
    --tables "replay=1step,aux=nstep:3" \
    --obs-dim 4 --act-dim 2 \
    2>"$1" &
}

# Each server binds an ephemeral port and prints the RESOLVED endpoint
# on its `listening on` stderr line; parse those to build the mesh.
endpoint_of() {
  local log="$1" ep=""
  for _ in $(seq 1 100); do
    ep=$(sed -n 's#.*listening on \(tcp://[0-9.]*:[0-9]*\).*#\1#p' "$log" | head -n 1)
    [ -n "$ep" ] && break
    sleep 0.1
  done
  [ -n "$ep" ] || { echo "mesh server ($log) never reported its endpoint" >&2; return 1; }
  echo "$ep"
}

serve_mesh_member "$dir/mesh1.log"
mesh_pid1=$!
serve_mesh_member "$dir/mesh2.log"
mesh_pid2=$!

cleanup_mesh() {
  kill "$mesh_pid1" "$mesh_pid2" 2>/dev/null || true
}
trap cleanup_mesh EXIT

ep1=$(endpoint_of "$dir/mesh1.log")
ep2=$(endpoint_of "$dir/mesh2.log")

./target/release/pal mesh-smoke --endpoints "$ep1,$ep2" --capacity 4096 --shards 4

# mesh-smoke ends with a Shutdown RPC to every server.
wait "$mesh_pid1"
wait "$mesh_pid2"
trap - EXIT

# --- Multi-tenant phase: budgets, ACLs, pluggable eviction, v1 restore. ---
# Flags must mirror tenant-smoke's hard-coded arithmetic (budget 48,
# writer cap 1, hot=LIFO@16, cold=FIFO@16, dims 2/1, free sampling),
# and --restore-state points at the COMMITTED legacy PALSTAT1 fixture:
# a server that can no longer read v1 files dies right here.
tenant_socket="$dir/tenant.sock"
./target/release/pal serve \
  --socket "$tenant_socket" \
  --buffer uniform --warmup 1 --rate-limit unlimited \
  --tables "hot=1step@16,remove=lifo,cold=1step@16" \
  --obs-dim 2 --act-dim 1 \
  --writer-budget 48 --max-writers-per-table 1 \
  --restore-state rust/tests/fixtures/palstat1 &
tenant_pid=$!

cleanup_tenant() {
  kill "$tenant_pid" 2>/dev/null || true
}
trap cleanup_tenant EXIT

for _ in $(seq 1 100); do
  [ -S "$tenant_socket" ] && break
  sleep 0.1
done
[ -S "$tenant_socket" ] || { echo "tenant server socket never appeared" >&2; exit 1; }

./target/release/pal tenant-smoke --socket "$tenant_socket"

# tenant-smoke ends with a Shutdown RPC.
wait "$tenant_pid"
trap - EXIT

echo "remote replay smoke OK ($dir): UDS phase + 2-server TCP mesh ($ep1 $ep2) + multi-tenant phase"
