#!/usr/bin/env bash
# CI remote replay smoke: start `pal serve` on a Unix socket, then run
# `pal remote-smoke` against it — a deterministic collect/sample phase
# whose checkpoint must be BYTE-identical to an in-process twin, a
# concurrent multi-client soak with exact sample-to-insert accounting
# over the Stats RPC, and a clean Shutdown RPC. The script then asserts
# the serving process exited 0 and wrote its --save-state replay state.
set -euo pipefail
cd "$(dirname "$0")/.."

dir="${1:-$(mktemp -d)}"
socket="$dir/replay.sock"
state_dir="$dir/state"

cargo build --release --bin pal

# Server and smoke client must agree on the table layout: remote-smoke
# drives the state-smoke shape (sharded prioritized `replay` 1step
# under a σ=1 ratio limiter + free-running `aux` nstep:3, warmup 64).
./target/release/pal serve \
  --socket "$socket" \
  --capacity 4096 --shards 4 --warmup 64 --rate-limit 1.0 \
  --tables "replay=1step,aux=nstep:3" \
  --obs-dim 4 --act-dim 2 \
  --save-state "$state_dir" &
server_pid=$!

cleanup() {
  kill "$server_pid" 2>/dev/null || true
}
trap cleanup EXIT

# Wait for the socket to come up.
for _ in $(seq 1 100); do
  [ -S "$socket" ] && break
  sleep 0.1
done
[ -S "$socket" ] || { echo "server socket never appeared" >&2; exit 1; }

./target/release/pal remote-smoke --socket "$socket" --capacity 4096 --shards 4

# The Shutdown RPC must end the serving process cleanly...
wait "$server_pid"
trap - EXIT

# ...and its clean-shutdown state save must exist.
[ -f "$state_dir/replay_state.bin" ] || {
  echo "server did not write replay_state.bin on shutdown" >&2
  exit 1
}

echo "remote replay smoke OK ($dir)"
