#!/usr/bin/env bash
# CI chaos restart drill: run `pal chaos-smoke`, the deterministic
# fault-tolerance gate for the remote replay front-end. The drill pipes
# a 3-writer/2-sampler soak through a seeded chaos proxy (injected
# delays, shredded writes, connection resets), then hard-kills the
# server mid-run and restarts it from its checkpoint, then drives a
# writer through a full outage past its spill cap. It must end with
# zero lost or duplicated steps (exact client-vs-Stats accounting),
# every overflow drop accounted, and a final checkpoint byte-identical
# to an unfaulted in-process twin. Blocking — a broken reconnect,
# session-resumption, or spill path must never merge.
set -euo pipefail
cd "$(dirname "$0")/.."

dir="${1:-$(mktemp -d)}"

cargo build --release --bin pal

out=$(./target/release/pal chaos-smoke --dir "$dir")
echo "$out"
case "$out" in
  *"chaos-smoke OK"*) ;;
  *)
    echo "chaos-smoke did not report success" >&2
    exit 1
    ;;
esac

# Same drill over loopback TCP (ephemeral ports): the transport swap
# must change nothing about the fault-tolerance contract.
out_tcp=$(./target/release/pal chaos-smoke --dir "$dir/tcp" --tcp)
echo "$out_tcp"
case "$out_tcp" in
  *"chaos-smoke OK"*) ;;
  *)
    echo "chaos-smoke --tcp did not report success" >&2
    exit 1
    ;;
esac

# Mesh kill-and-rejoin drill: a 3-server mesh loses one member
# mid-run (hard kill through a blackholed proxy), must keep sampling
# from the survivors with the victim marked Down, fail a stranded
# writer over to a live server with zero drops, restart the victim
# from its checkpoint and watch it rejoin (health Up, affinity
# fail-back), then live-drain a second server into a peer. Exact
# mesh-wide accounting — every append lands exactly once across
# failover, rejoin, and drain — is asserted inside the drill.
out_mesh=$(./target/release/pal mesh-chaos-smoke --dir "$dir/mesh")
echo "$out_mesh"
case "$out_mesh" in
  *"mesh-chaos-smoke OK"*) ;;
  *)
    echo "mesh-chaos-smoke did not report success" >&2
    exit 1
    ;;
esac
