#!/usr/bin/env bash
# CI checkpoint round-trip smoke: a short synthetic train run saves its
# replay-service state (`--phase collect`), then a SECOND process — the
# "restarted" run — rebuilds the service, restores (`--phase resume`),
# and fails unless buffer sizes, total priority mass and rate-limiter
# counters all equal the snapshotted values and the resumed service
# keeps accepting traffic under the ratio bound.
set -euo pipefail
cd "$(dirname "$0")/.."

dir="${1:-$(mktemp -d)}"
cargo run --release --bin pal -- state-smoke --dir "$dir" --phase collect
cargo run --release --bin pal -- state-smoke --dir "$dir" --phase resume
echo "checkpoint round-trip smoke OK ($dir)"
