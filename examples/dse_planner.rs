//! Design-space exploration demo (paper §V-D / Fig 12).
//!
//!     cargo run --release --example dse_planner -- --cores 8 --ratio 1.0
//!
//! 1. Measures the replay buffer's per-op costs live on this machine.
//! 2. Builds f_a(x) / f_l(x) throughput curves with the multicore DES.
//! 3. Solves Eq. 5 by exhaustive search and prints the chosen core split.
//! 4. Sweeps the replay-shard dimension of the design space (S ∈
//!    {1,2,4,8,16}) and reports the planner's shard choice.

use pal_rl::dse::{explore, render_curves, CostProfile};
use pal_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let a = Args::parse(std::env::args().skip(1))?;
    let cores: usize = a.parse_or("cores", 8)?;
    let ratio: f64 = a.parse_or("ratio", 1.0)?;
    let algo = a.str_or("algo", "dqn");
    let env = a.str_or("env", "CartPole-v1");

    println!("measuring buffer op costs on this machine ...");
    let rep = CostProfile::representative(&algo, &env);
    let measured = CostProfile::measure(rep.costs.act_ns, rep.costs.env_ns, rep.costs.learn_ns);
    println!(
        "  insert lock {} ns | insert copy {} ns | sample(64) lock {} ns | update(64) {} ns",
        measured.costs.insert_lock_ns,
        measured.costs.insert_copy_ns,
        measured.costs.sample_lock_ns,
        measured.costs.update_lock_ns
    );

    println!("\nthroughput profiles for {algo}@{env} (DES projection):");
    println!("{}", render_curves(&measured, cores));

    let plan = explore(&measured, cores, ratio);
    println!(
        "Eq.5 solution for M={cores}, update_interval={ratio}: \
         {} actors + {} learners",
        plan.actors, plan.learners
    );
    println!(
        "  collection {:.0} steps/s  vs  consumption {:.0} batches/s \
         (ratio mismatch {:.1}%)",
        plan.collect_throughput,
        plan.consume_throughput,
        plan.mismatch * 100.0
    );

    // Joint simulation sanity check of the chosen split.
    let joint = measured.joint(plan.actors, plan.learners, cores);
    println!(
        "  joint simulation: collect {:.0}/s, consume {:.0}/s",
        joint.collect_per_sec, joint.consume_per_sec
    );

    // Replay-shard dimension of the design space: best balanced
    // throughput per shard count (each with its own Eq.5 core split).
    let candidates = a.usize_list("shards", &[1, 2, 4, 8, 16])?;
    let sweep = measured.shard_sweep(cores, ratio, &candidates);
    println!("\nshard sweep (best balanced throughput per S at M={cores}):");
    for &(s, tput) in &sweep {
        println!("  S={s:2}  {tput:10.0} steps/s");
    }
    let (best_s, best_t) = CostProfile::pick_best_shards(&sweep);
    println!("planner's shard choice: S={best_s} ({best_t:.0} steps/s)");
    Ok(())
}
