//! Quickstart: train DQN on CartPole-v1 with 1 actor + 1 learner.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the minimal public API: build a `TrainConfig`, call
//! `train`, read the report.

use pal_rl::coordinator::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.total_env_steps = 15_000;
    cfg.warmup_steps = 500;
    cfg.exploration.eps_decay_steps = 6_000;
    cfg.lr = 1e-3;
    cfg.stop_at_reward = Some(200.0);
    cfg.log_every_secs = 5.0;
    cfg.seed = 42;

    println!("training dqn on CartPole-v1 (stop at mean return 200)...");
    let report = train(&cfg)?;

    println!(
        "\nfinished: {} env steps / {} learn steps / {} episodes in {:.1}s",
        report.env_steps, report.learn_steps, report.episodes, report.elapsed_secs
    );
    println!(
        "throughput: {:.0} env steps/s, {:.0} learn steps/s",
        report.env_steps_per_sec, report.learn_steps_per_sec
    );
    println!("final mean return (last 128 episodes): {:.1}", report.final_mean_return);
    if report.reached_target {
        println!("target reached — CartPole balanced.");
    }
    // ASCII reward curve.
    let curve = &report.curve;
    if !curve.is_empty() {
        println!("\nreward curve (each row = 1/20th of training):");
        let chunk = (curve.len() / 20).max(1);
        for w in curve.chunks(chunk) {
            let mean: f32 =
                w.iter().map(|p| p.episode_return).sum::<f32>() / w.len() as f32;
            let bars = (mean / 10.0).clamp(0.0, 50.0) as usize;
            println!("{:>8} steps | {:6.1} {}", w[0].env_steps, mean, "#".repeat(bars));
        }
    }
    Ok(())
}
