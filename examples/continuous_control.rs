//! Continuous-control example: DDPG / TD3 / SAC on Pendulum-v1 (the
//! paper's continuous-action benchmark family, §VI-A).
//!
//!     cargo run --release --example continuous_control -- --algo sac
//!
//! Shows the multi-graph agents (twin critics, delayed policy updates,
//! reparameterized sampling) running through the same coordinator.

use pal_rl::coordinator::{train, TrainConfig};
use pal_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let a = Args::parse(std::env::args().skip(1))?;
    let algo = a.str_or("algo", "sac");
    let steps: usize = a.parse_or("steps", 8_000)?;

    let mut cfg = TrainConfig::new(&algo, "Pendulum-v1");
    cfg.total_env_steps = steps;
    cfg.warmup_steps = 500;
    cfg.update_interval = 2.0; // 1 learn per 2 env steps: keeps CPU sane
    cfg.lr = 1e-3;
    cfg.exploration.action_noise = 0.15;
    cfg.log_every_secs = 5.0;
    cfg.seed = 1;

    println!("training {algo} on Pendulum-v1 for {steps} env steps ...");
    let report = train(&cfg)?;
    println!(
        "\n{} episodes, mean return {:.1} (random ≈ -1200, good ≈ -250)",
        report.episodes, report.final_mean_return
    );
    println!(
        "{:.0} env steps/s | {:.0} learn steps/s | {:.1}s wall",
        report.env_steps_per_sec, report.learn_steps_per_sec, report.elapsed_secs
    );

    // Return trajectory: first vs last quartile of episodes.
    let c = &report.curve;
    if c.len() >= 8 {
        let q = c.len() / 4;
        let first: f32 = c[..q].iter().map(|p| p.episode_return).sum::<f32>() / q as f32;
        let last: f32 =
            c[c.len() - q..].iter().map(|p| p.episode_return).sum::<f32>() / q as f32;
        println!("first-quartile mean return {first:.1} → last-quartile {last:.1}");
    }
    Ok(())
}
