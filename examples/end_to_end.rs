//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Proves all layers compose on real workloads:
//!   1. DQN on CartPole-v1 trained until the 195-return threshold (or
//!      the step budget), through the full parallel stack — rust actors
//!      and learners executing AOT-compiled JAX/Pallas graphs on PJRT,
//!      feeding the K-ary prioritized replay buffer.
//!   2. SAC on Pendulum-v1 for a fixed budget, reporting the return
//!      improvement.
//!
//! Loss/reward curves are written to e2e_cartpole.csv / e2e_pendulum.csv.
//!
//!     cargo run --release --example end_to_end            # full run
//!     cargo run --release --example end_to_end -- --quick # CI-sized

use pal_rl::coordinator::{train, TrainConfig};
use pal_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let a = Args::parse(std::env::args().skip(1))?;
    let quick = a.flag("quick");

    // ---------------------------------------------------------- CartPole
    let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
    cfg.total_env_steps = if quick { 6_000 } else { 60_000 };
    cfg.warmup_steps = 1_000;
    cfg.exploration.eps_decay_steps = if quick { 3_000 } else { 10_000 };
    cfg.lr = 5e-4;
    cfg.update_interval = 1.0;
    cfg.stop_at_reward = Some(195.0);
    cfg.log_every_secs = 10.0;
    cfg.seed = 3;

    println!("=== E2E 1/2: DQN @ CartPole-v1 (target mean return 195) ===");
    let t0 = std::time::Instant::now();
    let r1 = train(&cfg)?;
    println!(
        "CartPole: {} steps, {} episodes, mean return {:.1}, reached={} in {:.0}s",
        r1.env_steps,
        r1.episodes,
        r1.final_mean_return,
        r1.reached_target,
        t0.elapsed().as_secs_f64()
    );
    write_csv("e2e_cartpole.csv", &r1)?;

    // ---------------------------------------------------------- Pendulum
    let mut cfg2 = TrainConfig::new("sac", "Pendulum-v1");
    cfg2.total_env_steps = if quick { 3_000 } else { 20_000 };
    cfg2.warmup_steps = 500;
    cfg2.update_interval = 2.0;
    cfg2.lr = 1e-3;
    cfg2.log_every_secs = 10.0;
    cfg2.seed = 5;

    println!("\n=== E2E 2/2: SAC @ Pendulum-v1 ===");
    let r2 = train(&cfg2)?;
    let (first, last) = quartiles(&r2);
    println!(
        "Pendulum: {} steps, {} episodes, first-q return {:.0} → last-q {:.0}",
        r2.env_steps, r2.episodes, first, last
    );
    write_csv("e2e_pendulum.csv", &r2)?;

    // ---------------------------------------------------------- verdict
    let cartpole_ok = r1.reached_target || r1.final_mean_return > 100.0;
    let pendulum_ok = last > first + 100.0 || last > -400.0;
    println!(
        "\nE2E verdict: cartpole {} | pendulum {}",
        if cartpole_ok { "LEARNED" } else { "WEAK" },
        if pendulum_ok { "LEARNED" } else { "WEAK" },
    );
    Ok(())
}

fn quartiles(r: &pal_rl::coordinator::TrainReport) -> (f64, f64) {
    let c = &r.curve;
    if c.len() < 8 {
        return (f64::NAN, f64::NAN);
    }
    let q = c.len() / 4;
    let first = c[..q].iter().map(|p| p.episode_return as f64).sum::<f64>() / q as f64;
    let last =
        c[c.len() - q..].iter().map(|p| p.episode_return as f64).sum::<f64>() / q as f64;
    (first, last)
}

fn write_csv(path: &str, r: &pal_rl::coordinator::TrainReport) -> std::io::Result<()> {
    let mut s = String::from("wall_secs,env_steps,learn_steps,episode_return,loss_ema\n");
    for p in &r.curve {
        s.push_str(&format!(
            "{:.3},{},{},{},{}\n",
            p.wall_secs, p.env_steps, p.learn_steps, p.episode_return, p.loss_ema
        ));
    }
    std::fs::write(path, s)?;
    println!("curve -> {path}");
    Ok(())
}
