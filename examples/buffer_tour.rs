//! Tour of the replay-buffer public API — the paper's core data structure
//! (§IV) — including the Table-I style resource accounting.
//!
//!     cargo run --release --example buffer_tour

use pal_rl::replay::{
    GlobalLockReplay, PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch,
    ShardedPrioritizedReplay, Transition,
};
use pal_rl::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn tr(v: f32) -> Transition {
    Transition {
        obs: vec![v; 8],
        action: vec![v; 2],
        next_obs: vec![v + 1.0; 8],
        reward: v.sin(),
        done: false,
    }
}

fn main() {
    // 1. Build the K-ary prioritized buffer (K=64: cache-aligned groups).
    let buf = Arc::new(PrioritizedReplay::new(PrioritizedConfig {
        capacity: 65_536,
        obs_dim: 8,
        act_dim: 2,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 1,
    }));
    buf.stats.enable_timing();

    // 2. Insertions (lazy writing: the data copy happens outside locks).
    for i in 0..10_000 {
        buf.insert(&tr(i as f32));
    }
    println!("inserted 10k transitions; len = {}", buf.len());
    println!("Σ priorities (root read, Θ(1)) = {:.1}", buf.total_priority());

    // 3. Prioritized sampling with importance weights.
    let mut rng = Rng::new(7);
    let mut batch = SampleBatch::with_capacity(64, 8, 2);
    buf.sample(64, &mut rng, &mut batch);
    println!(
        "sampled 64: first idx {} p={:.3} is_w={:.3}",
        batch.indices[0], batch.priorities[0], batch.is_weights[0]
    );

    // 4. Priority feedback (|TD| -> (|td|+eps)^alpha).
    let tds: Vec<f32> = (0..64).map(|i| 0.01 + i as f32 * 0.1).collect();
    buf.update_priorities(&batch.indices, &tds);
    println!("updated priorities; max_priority = {:.3}", buf.max_priority());

    // 5. Concurrent producers/consumers over one shared buffer.
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..2 {
            let b = Arc::clone(&buf);
            s.spawn(move || {
                for i in 0..20_000 {
                    b.insert(&tr((t * 100_000 + i) as f32));
                }
            });
        }
        let b = Arc::clone(&buf);
        s.spawn(move || {
            let mut rng = Rng::new(9);
            let mut out = SampleBatch::default();
            for _ in 0..2_000 {
                if b.sample(64, &mut rng, &mut out) {
                    let idx = out.indices.clone();
                    b.update_priorities(&idx, &vec![0.5; idx.len()]);
                }
            }
        });
    });
    println!("2 inserters + 1 sampler/updater finished in {:?}", t0.elapsed());

    // 6. Table-I style resource accounting from the lock instrumentation.
    let s = buf.stats.snapshot();
    println!("\nTable I — resource utilization of various operations");
    println!("{:<20} {:>12} {:>18}", "operation", "count", "locks touched");
    println!("{:<20} {:>12} {:>18}", "insertion", s.inserts, "tree (2x), storage");
    println!("{:<20} {:>12} {:>18}", "sampling", s.samples, "tree, storage");
    println!("{:<20} {:>12} {:>18}", "priority retrieval", s.retrievals, "last level");
    println!("{:<20} {:>12} {:>18}", "priority update", s.updates, "tree");
    println!(
        "\nlock stats: global acquired {} (avg hold {} ns), leaf acquired {} \
         (avg hold {} ns), storage copies {} ns total (outside locks)",
        s.global_acquisitions,
        s.global_held_ns / s.global_acquisitions.max(1),
        s.leaf_acquisitions,
        s.leaf_held_ns / s.leaf_acquisitions.max(1),
        s.storage_copy_ns,
    );

    // 7. Contrast with the baseline: everything under one global lock.
    let base = GlobalLockReplay::new(65_536, 8, 2, 0.6, 0.4);
    let t1 = Instant::now();
    for i in 0..10_000 {
        base.insert(&tr(i as f32));
    }
    println!(
        "\nbaseline (binary tree + global lock): 10k inserts in {:?} \
         (vs PAL: copies outside the lock)",
        t1.elapsed()
    );

    // 8. Sharded buffer: S independent sub-trees, actor-affinity insert
    //    routing, two-level sampling, batched priority feedback.
    let sharded = Arc::new(ShardedPrioritizedReplay::new(PrioritizedConfig {
        capacity: 65_536,
        obs_dim: 8,
        act_dim: 2,
        fanout: 64,
        alpha: 0.6,
        beta: 0.4,
        lazy_writing: true,
        shards: 4,
    }));
    for actor in 0..4 {
        for i in 0..2_500 {
            sharded.insert_from(actor, &tr(i as f32)); // actor -> shard actor%4
        }
    }
    let mut out = SampleBatch::default();
    sharded.sample(64, &mut rng, &mut out); // two-level: shard pick, then descent
    let before = sharded.merged_stats().global_acquisitions;
    let pairs: Vec<(usize, f32)> =
        out.indices.iter().map(|&i| (i, 0.5)).collect();
    sharded.update_priorities_batched(&pairs); // <= 1 lock pair per shard
    let after = sharded.merged_stats().global_acquisitions;
    println!(
        "\nsharded (S=4): len {}, Σ priorities {:.1}, 64-pair priority batch \
         took {} lock acquisitions (vs 64 unbatched)",
        sharded.len(),
        sharded.total_priority(),
        after - before,
    );
}
