"""Environment specs mirrored from `rust/src/env/` (single source of truth
for shapes at AOT time; rust/tests/manifest_check.rs cross-checks them)."""

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    n_actions: Optional[int] = None  # discrete envs
    act_dim: Optional[int] = None    # continuous envs
    act_high: float = 1.0

    @property
    def discrete(self) -> bool:
        return self.n_actions is not None

    @property
    def flat_act_dim(self) -> int:
        return 1 if self.discrete else self.act_dim


ENVS = {
    "CartPole-v1": EnvSpec("CartPole-v1", obs_dim=4, n_actions=2),
    "MountainCar-v0": EnvSpec("MountainCar-v0", obs_dim=2, n_actions=3),
    "Acrobot-v1": EnvSpec("Acrobot-v1", obs_dim=6, n_actions=3),
    "RandomMDP-v0": EnvSpec("RandomMDP-v0", obs_dim=16, n_actions=4),
    "Pendulum-v1": EnvSpec("Pendulum-v1", obs_dim=3, act_dim=1, act_high=2.0),
    "MountainCarContinuous-v0": EnvSpec(
        "MountainCarContinuous-v0", obs_dim=2, act_dim=1, act_high=1.0
    ),
    "LunarLanderLite-v0": EnvSpec("LunarLanderLite-v0", obs_dim=8, act_dim=2, act_high=1.0),
}
