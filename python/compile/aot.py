"""AOT driver: lower every (algo, env) graph to HLO TEXT + manifest.

HLO *text* (never ``.serialize()``): jax >= 0.5 emits HloModuleProtos with
64-bit instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Outputs (default ``../artifacts``):

    <id>.<graph>.hlo.txt      one file per lowered graph
    <id>.params.bin           initial parameters, raw little-endian f32
    manifest.json             everything the rust runtime needs: shapes,
                              param table w/ flat offsets, graph signatures

Run ``python -m compile.aot --help`` from ``python/``.
"""

import argparse
import hashlib
import json
import os
import sys

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from .envs import ENVS
from .model import ALGOS, AlgoBuild, build

# The default artifact set: every algorithm on the benchmarks the paper
# trains (discrete algos on discrete envs, continuous on continuous).
DEFAULT_CONFIGS = [
    ("dqn", "CartPole-v1"),
    ("ddqn", "CartPole-v1"),
    ("dqn", "MountainCar-v0"),
    ("dqn", "Acrobot-v1"),
    ("dqn", "RandomMDP-v0"),
    ("ddpg", "Pendulum-v1"),
    ("td3", "Pendulum-v1"),
    ("sac", "Pendulum-v1"),
    ("ddpg", "LunarLanderLite-v0"),
    ("td3", "LunarLanderLite-v0"),
    ("sac", "LunarLanderLite-v0"),
    ("sac", "MountainCarContinuous-v0"),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_build(b: AlgoBuild, out_dir: str, cfg_id: str) -> dict:
    """Lower all graphs of one AlgoBuild; return its manifest entry."""
    graphs = {}
    for gname, spec in b.graphs.items():
        lowered = jax.jit(spec.fn).lower(*spec.example_args)
        text = to_hlo_text(lowered)
        fname = f"{cfg_id}.{gname}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        graphs[gname] = {
            "file": fname,
            "inputs": [
                {"name": nm, "shape": list(a.shape)}
                for nm, a in zip(spec.input_names, spec.example_args)
            ],
            "outputs": spec.output_names,
            "grad_slice": list(spec.grad_slice) if spec.grad_slice else None,
        }

    # Initial parameters: one flat f32 blob + offsets table.
    flat = np.concatenate([p.reshape(-1) for p in b.init_params]).astype("<f4")
    pfile = f"{cfg_id}.params.bin"
    flat.tofile(os.path.join(out_dir, pfile))

    params = []
    off = 0
    for name, p in zip(b.param_names, b.init_params):
        params.append({"name": name, "shape": list(p.shape), "offset": off,
                       "size": int(p.size)})
        off += int(p.size)

    env = b.env
    return {
        "id": cfg_id,
        "algo": b.algo,
        "env": env.name,
        "obs_dim": env.obs_dim,
        "flat_act_dim": env.flat_act_dim,
        "n_actions": env.n_actions,
        "act_dim": env.act_dim,
        "act_high": env.act_high,
        "discrete": env.discrete,
        "hidden": b.hidden,
        "batch_size": b.batch_size,
        "gamma": b.gamma,
        "params_file": pfile,
        "total_param_size": off,
        "params": params,
        "graphs": graphs,
        "extra": b.extra,
    }


def input_fingerprint() -> str:
    """Hash of the compile-path sources, for Makefile staleness checks."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in os.walk(base):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--hidden", type=int, nargs="*", default=[64, 64])
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--only",
        nargs="*",
        default=None,
        metavar="ALGO@ENV",
        help="subset of configs, e.g. dqn@CartPole-v1 sac@Pendulum-v1",
    )
    args = ap.parse_args(argv)

    configs = DEFAULT_CONFIGS
    if args.only:
        configs = []
        for spec in args.only:
            algo, env = spec.split("@", 1)
            if algo not in ALGOS:
                sys.exit(f"unknown algo {algo!r} (have {ALGOS})")
            if env not in ENVS:
                sys.exit(f"unknown env {env!r} (have {sorted(ENVS)})")
            configs.append((algo, env))

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for algo, env_name in configs:
        cfg_id = f"{algo}_{env_name}"
        print(f"[aot] lowering {cfg_id} ...", flush=True)
        b = build(
            algo,
            ENVS[env_name],
            hidden=tuple(args.hidden),
            batch_size=args.batch_size,
            gamma=args.gamma,
            seed=args.seed,
        )
        entries.append(lower_build(b, args.out_dir, cfg_id))

    manifest = {
        "version": 1,
        "fingerprint": input_fingerprint(),
        "hidden": args.hidden,
        "batch_size": args.batch_size,
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} configs to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
