"""L2: JAX compute graphs for every supported algorithm.

Each algorithm contributes:

* ``act``          — policy forward for one observation (B=1), the graph
                     actors execute every environment step;
* ``learn`` / ``learn_critic`` + ``learn_actor``
                   — loss + gradients + |TD| priorities for one sampled
                     batch, the graph learners execute. Gradients are
                     returned per-parameter, aligned with a slice of the
                     parameter list (the rust parameter server aggregates
                     them and applies Adam — paper §V-B).

Parameters are a FLAT list of arrays (w0, b0, w1, b1, ...) so the lowered
HLO signature is position-based and the rust side needs no pytrees. Every
graph takes the full online (and, where needed, target) parameter list;
learn graphs report which slice their gradient outputs correspond to via
``grad_slice`` in the build metadata.

The MLP hot-spot runs through the L1 Pallas kernels
(`kernels.fused_linear`, `kernels.td_error`); everything else is jnp glue
that XLA fuses around them.
"""

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .envs import EnvSpec
from .kernels.fused_linear import fused_linear
from .kernels.td_error import td_loss

Params = List[jnp.ndarray]

ALGOS = ("dqn", "ddqn", "ddpg", "td3", "sac")
SAC_LOG_STD_MIN, SAC_LOG_STD_MAX = -20.0, 2.0


# --------------------------------------------------------------------------
# MLP built on the Pallas fused_linear kernel.
# --------------------------------------------------------------------------

def mlp_init(rng: np.random.Generator, dims: List[int]) -> List[np.ndarray]:
    """He/fan-in init; returns flat [w0, b0, w1, b1, ...] f32 arrays."""
    out: List[np.ndarray] = []
    for i in range(len(dims) - 1):
        fan_in = dims[i]
        bound = 1.0 / math.sqrt(fan_in)
        out.append(rng.uniform(-bound, bound, (dims[i], dims[i + 1])).astype(np.float32))
        out.append(rng.uniform(-bound, bound, (dims[i + 1],)).astype(np.float32))
    return out


def mlp_apply(params: Params, x, hidden_act="relu", out_act="none"):
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        act = out_act if i == n_layers - 1 else hidden_act
        h = fused_linear(h, params[2 * i], params[2 * i + 1], act)
    return h


# --------------------------------------------------------------------------
# Build-spec plumbing.
# --------------------------------------------------------------------------

@dataclass
class GraphSpec:
    """One lowerable graph: fn(*example_args) with named inputs/outputs."""
    fn: Callable
    example_args: List[np.ndarray]
    input_names: List[str]
    output_names: List[str]
    # Half-open slice of the full param list that `grads` outputs cover.
    grad_slice: Optional[Tuple[int, int]] = None


@dataclass
class AlgoBuild:
    algo: str
    env: EnvSpec
    hidden: List[int]
    batch_size: int
    gamma: float
    init_params: List[np.ndarray]
    param_names: List[str]
    graphs: Dict[str, GraphSpec] = field(default_factory=dict)
    extra: Dict[str, float] = field(default_factory=dict)


def _zeros(*shape):
    return np.zeros(shape, np.float32)


def _param_examples(params: List[np.ndarray]) -> List[np.ndarray]:
    return [np.zeros_like(p) for p in params]


def _batch_examples(env: EnvSpec, batch: int) -> List[np.ndarray]:
    return [
        _zeros(batch, env.obs_dim),          # obs
        _zeros(batch, env.flat_act_dim),     # action
        _zeros(batch, env.obs_dim),          # next_obs
        _zeros(batch),                       # reward
        _zeros(batch),                       # done
        _zeros(batch),                       # is_weights
    ]


BATCH_NAMES = ["obs", "action", "next_obs", "reward", "done", "is_weights"]


def _names(prefix: str, n_arrays: int) -> List[str]:
    out = []
    for i in range(n_arrays // 2):
        out += [f"{prefix}/w{i}", f"{prefix}/b{i}"]
    return out


# --------------------------------------------------------------------------
# DQN / DDQN.
# --------------------------------------------------------------------------

def build_dqn(env: EnvSpec, hidden, batch_size, gamma, double=False, seed=0) -> AlgoBuild:
    assert env.discrete, "DQN needs a discrete action space"
    rng = np.random.default_rng(seed)
    dims = [env.obs_dim, *hidden, env.n_actions]
    params0 = mlp_init(rng, dims)
    n = len(params0)

    def q_net(params, obs):
        return mlp_apply(params, obs)

    def act(*args):
        params, obs = list(args[:n]), args[n]
        q = q_net(params, obs)
        return (jnp.argmax(q, axis=-1).astype(jnp.float32),)

    def learn(*args):
        params = list(args[:n])
        tparams = list(args[n : 2 * n])
        obs, action, next_obs, reward, done, isw = args[2 * n : 2 * n + 6]

        def loss_fn(params):
            q = q_net(params, obs)
            a_idx = action[:, 0].astype(jnp.int32)
            qa = jnp.take_along_axis(q, a_idx[:, None], axis=1)[:, 0]
            if double:
                next_online = q_net(params, next_obs)
                next_a = jnp.argmax(next_online, axis=-1)
                next_q_all = q_net(tparams, next_obs)
                next_q = jnp.take_along_axis(next_q_all, next_a[:, None], axis=1)[:, 0]
            else:
                next_q = jnp.max(q_net(tparams, next_obs), axis=-1)
            target = reward + gamma * (1.0 - done) * next_q
            loss_vec, td_abs = td_loss(qa, jax.lax.stop_gradient(target), isw, "huber", 1.0)
            return jnp.mean(loss_vec), td_abs

        (loss, td_abs), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return (*grads, td_abs, loss)

    name = "ddqn" if double else "dqn"
    b = AlgoBuild(
        algo=name,
        env=env,
        hidden=list(hidden),
        batch_size=batch_size,
        gamma=gamma,
        init_params=params0,
        param_names=_names("q", n),
    )
    pex = _param_examples(params0)
    b.graphs["act"] = GraphSpec(
        act,
        pex + [_zeros(1, env.obs_dim)],
        [f"p:{nm}" for nm in b.param_names] + ["obs"],
        ["action"],
    )
    b.graphs["learn"] = GraphSpec(
        learn,
        pex + pex + _batch_examples(env, batch_size),
        [f"p:{nm}" for nm in b.param_names]
        + [f"t:{nm}" for nm in b.param_names]
        + BATCH_NAMES,
        [f"g:{nm}" for nm in b.param_names] + ["td_abs", "loss"],
        grad_slice=(0, n),
    )
    return b


# --------------------------------------------------------------------------
# Continuous-control nets shared by DDPG / TD3 / SAC.
# --------------------------------------------------------------------------

def _actor_apply(params, obs, act_high):
    """Deterministic tanh actor (DDPG/TD3)."""
    return act_high * mlp_apply(params, obs, out_act="tanh")


def _critic_apply(params, obs, action):
    x = jnp.concatenate([obs, action], axis=-1)
    return mlp_apply(params, x)[:, 0]


# --------------------------------------------------------------------------
# DDPG.
# --------------------------------------------------------------------------

def build_ddpg(env: EnvSpec, hidden, batch_size, gamma, seed=0) -> AlgoBuild:
    assert not env.discrete, "DDPG needs a continuous action space"
    rng = np.random.default_rng(seed)
    actor0 = mlp_init(rng, [env.obs_dim, *hidden, env.act_dim])
    critic0 = mlp_init(rng, [env.obs_dim + env.act_dim, *hidden, 1])
    na, nc = len(actor0), len(critic0)
    n = na + nc
    params0 = actor0 + critic0
    high = env.act_high

    def split(params):
        return params[:na], params[na:]

    def act(*args):
        actor, obs = list(args[:na]), args[na]
        return (_actor_apply(actor, obs, high),)

    def learn(*args):
        params = list(args[:n])
        tparams = list(args[n : 2 * n])
        obs, action, next_obs, reward, done, isw = args[2 * n : 2 * n + 6]
        t_actor, t_critic = split(tparams)

        next_a = _actor_apply(t_actor, next_obs, high)
        next_q = _critic_apply(t_critic, next_obs, next_a)
        target = reward + gamma * (1.0 - done) * next_q

        def critic_loss(critic):
            q = _critic_apply(critic, obs, action)
            loss_vec, td_abs = td_loss(q, jax.lax.stop_gradient(target), isw, "mse", 1.0)
            return jnp.mean(loss_vec), td_abs

        def actor_loss(actor, critic):
            a = _actor_apply(actor, obs, high)
            return -jnp.mean(_critic_apply(critic, obs, a))

        actor_p, critic_p = split(params)
        (c_loss, td_abs), c_grads = jax.value_and_grad(critic_loss, has_aux=True)(critic_p)
        a_loss, a_grads = jax.value_and_grad(actor_loss)(actor_p, critic_p)
        return (*a_grads, *c_grads, td_abs, c_loss + a_loss)

    b = AlgoBuild(
        algo="ddpg",
        env=env,
        hidden=list(hidden),
        batch_size=batch_size,
        gamma=gamma,
        init_params=params0,
        param_names=_names("actor", na) + _names("critic", nc),
    )
    pex = _param_examples(params0)
    b.graphs["act"] = GraphSpec(
        act,
        pex[:na] + [_zeros(1, env.obs_dim)],
        [f"p:{nm}" for nm in b.param_names[:na]] + ["obs"],
        ["action"],
    )
    b.graphs["learn"] = GraphSpec(
        learn,
        pex + pex + _batch_examples(env, batch_size),
        [f"p:{nm}" for nm in b.param_names]
        + [f"t:{nm}" for nm in b.param_names]
        + BATCH_NAMES,
        [f"g:{nm}" for nm in b.param_names] + ["td_abs", "loss"],
        grad_slice=(0, n),
    )
    return b


# --------------------------------------------------------------------------
# TD3: twin critics, target policy smoothing, delayed actor updates
# (the delay schedule lives in the rust learner).
# --------------------------------------------------------------------------

def build_td3(
    env: EnvSpec,
    hidden,
    batch_size,
    gamma,
    seed=0,
    policy_noise=0.2,
    noise_clip=0.5,
) -> AlgoBuild:
    assert not env.discrete
    rng = np.random.default_rng(seed)
    actor0 = mlp_init(rng, [env.obs_dim, *hidden, env.act_dim])
    c1_0 = mlp_init(rng, [env.obs_dim + env.act_dim, *hidden, 1])
    c2_0 = mlp_init(rng, [env.obs_dim + env.act_dim, *hidden, 1])
    na, nc = len(actor0), len(c1_0)
    n = na + 2 * nc
    params0 = actor0 + c1_0 + c2_0
    high = env.act_high

    # Graph signatures are PRECISE (only arrays the computation actually
    # uses): jax prunes unused arguments at lowering time, so passing the
    # full parameter list would desynchronize the HLO signature from the
    # manifest.

    def act(*args):
        actor, obs = list(args[:na]), args[na]
        return (_actor_apply(actor, obs, high),)

    def learn_critic(*args):
        critics = list(args[: 2 * nc])
        t_actor = list(args[2 * nc : 2 * nc + na])
        t_c1 = list(args[2 * nc + na : 2 * nc + na + nc])
        t_c2 = list(args[2 * nc + na + nc : 2 * nc + na + 2 * nc])
        k = 2 * nc + na + 2 * nc
        obs, action, next_obs, reward, done, isw = args[k : k + 6]
        noise = args[k + 6]

        # Target policy smoothing (TD3 eq. 15).
        eps = jnp.clip(noise * policy_noise, -noise_clip, noise_clip) * high
        next_a = jnp.clip(_actor_apply(t_actor, next_obs, high) + eps, -high, high)
        next_q = jnp.minimum(
            _critic_apply(t_c1, next_obs, next_a), _critic_apply(t_c2, next_obs, next_a)
        )
        target = jax.lax.stop_gradient(reward + gamma * (1.0 - done) * next_q)

        def loss_fn(critics):
            c1, c2 = critics[:nc], critics[nc:]
            q1 = _critic_apply(c1, obs, action)
            q2 = _critic_apply(c2, obs, action)
            l1, td_abs = td_loss(q1, target, isw, "mse", 1.0)
            l2, _ = td_loss(q2, target, isw, "mse", 1.0)
            return jnp.mean(l1) + jnp.mean(l2), td_abs

        (loss, td_abs), grads = jax.value_and_grad(loss_fn, has_aux=True)(critics)
        return (*grads, td_abs, loss)

    def learn_actor(*args):
        actor_p = list(args[:na])
        c1 = list(args[na : na + nc])
        obs = args[na + nc]

        def loss_fn(actor):
            a = _actor_apply(actor, obs, high)
            return -jnp.mean(_critic_apply(c1, obs, a))

        loss, grads = jax.value_and_grad(loss_fn)(actor_p)
        zeros_td = jnp.zeros(obs.shape[0], jnp.float32)
        return (*grads, zeros_td, loss)

    b = AlgoBuild(
        algo="td3",
        env=env,
        hidden=list(hidden),
        batch_size=batch_size,
        gamma=gamma,
        init_params=params0,
        param_names=_names("actor", na) + _names("critic1", nc) + _names("critic2", nc),
        extra={"policy_noise": policy_noise, "noise_clip": noise_clip},
    )
    pex = _param_examples(params0)
    p_names = b.param_names
    b.graphs["act"] = GraphSpec(
        act,
        pex[:na] + [_zeros(1, env.obs_dim)],
        [f"p:{nm}" for nm in p_names[:na]] + ["obs"],
        ["action"],
    )
    b.graphs["learn_critic"] = GraphSpec(
        learn_critic,
        pex[na:] + pex + _batch_examples(env, batch_size)
        + [_zeros(batch_size, env.act_dim)],
        [f"p:{nm}" for nm in p_names[na:]]
        + [f"t:{nm}" for nm in p_names]
        + BATCH_NAMES
        + ["noise"],
        [f"g:{nm}" for nm in p_names[na:]] + ["td_abs", "loss"],
        grad_slice=(na, n),
    )
    b.graphs["learn_actor"] = GraphSpec(
        learn_actor,
        pex[: na + nc] + [_zeros(batch_size, env.obs_dim)],
        [f"p:{nm}" for nm in p_names[: na + nc]] + ["obs"],
        [f"g:{nm}" for nm in p_names[:na]] + ["td_abs", "loss"],
        grad_slice=(0, na),
    )
    return b


# --------------------------------------------------------------------------
# SAC (fixed temperature): stochastic tanh-Gaussian actor, twin critics.
# --------------------------------------------------------------------------

def _sac_actor_sample(actor, obs, noise, act_high):
    """Reparameterized tanh-Gaussian sample + log-prob."""
    out = mlp_apply(actor, obs)
    mean, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, SAC_LOG_STD_MIN, SAC_LOG_STD_MAX)
    std = jnp.exp(log_std)
    pre = mean + std * noise
    a = jnp.tanh(pre)
    # log N(pre; mean, std) with tanh change-of-variables.
    logp = (
        -0.5 * (((pre - mean) / std) ** 2 + 2.0 * log_std + jnp.log(2.0 * jnp.pi))
    ).sum(-1)
    logp -= (2.0 * (jnp.log(2.0) - pre - jax.nn.softplus(-2.0 * pre))).sum(-1)
    return act_high * a, logp


def build_sac(env: EnvSpec, hidden, batch_size, gamma, seed=0, alpha=0.2) -> AlgoBuild:
    assert not env.discrete
    rng = np.random.default_rng(seed)
    actor0 = mlp_init(rng, [env.obs_dim, *hidden, 2 * env.act_dim])
    c1_0 = mlp_init(rng, [env.obs_dim + env.act_dim, *hidden, 1])
    c2_0 = mlp_init(rng, [env.obs_dim + env.act_dim, *hidden, 1])
    na, nc = len(actor0), len(c1_0)
    n = na + 2 * nc
    params0 = actor0 + c1_0 + c2_0
    high = env.act_high

    # Precise signatures (see TD3 note): only arrays actually used.

    def act(*args):
        actor, obs, noise = list(args[:na]), args[na], args[na + 1]
        a, _ = _sac_actor_sample(actor, obs, noise, high)
        return (a,)

    def learn_critic(*args):
        actor_p = list(args[:na])
        critics = list(args[na:n])
        t_c1 = list(args[n : n + nc])
        t_c2 = list(args[n + nc : n + 2 * nc])
        k = n + 2 * nc
        obs, action, next_obs, reward, done, isw = args[k : k + 6]
        noise = args[k + 6]

        next_a, next_logp = _sac_actor_sample(actor_p, next_obs, noise, high)
        next_q = jnp.minimum(
            _critic_apply(t_c1, next_obs, next_a), _critic_apply(t_c2, next_obs, next_a)
        )
        target = jax.lax.stop_gradient(
            reward + gamma * (1.0 - done) * (next_q - alpha * next_logp)
        )

        def loss_fn(critics):
            c1, c2 = critics[:nc], critics[nc:]
            q1 = _critic_apply(c1, obs, action)
            q2 = _critic_apply(c2, obs, action)
            l1, td_abs = td_loss(q1, target, isw, "mse", 1.0)
            l2, _ = td_loss(q2, target, isw, "mse", 1.0)
            return jnp.mean(l1) + jnp.mean(l2), td_abs

        (loss, td_abs), grads = jax.value_and_grad(loss_fn, has_aux=True)(critics)
        return (*grads, td_abs, loss)

    def learn_actor(*args):
        actor_p = list(args[:na])
        c1 = list(args[na : na + nc])
        c2 = list(args[na + nc : n])
        obs, noise = args[n], args[n + 1]

        def loss_fn(actor):
            a, logp = _sac_actor_sample(actor, obs, noise, high)
            q = jnp.minimum(_critic_apply(c1, obs, a), _critic_apply(c2, obs, a))
            return jnp.mean(alpha * logp - q)

        loss, grads = jax.value_and_grad(loss_fn)(actor_p)
        zeros_td = jnp.zeros(obs.shape[0], jnp.float32)
        return (*grads, zeros_td, loss)

    b = AlgoBuild(
        algo="sac",
        env=env,
        hidden=list(hidden),
        batch_size=batch_size,
        gamma=gamma,
        init_params=params0,
        param_names=_names("actor", na) + _names("critic1", nc) + _names("critic2", nc),
        extra={"alpha": alpha},
    )
    pex = _param_examples(params0)
    p_names = b.param_names
    b.graphs["act"] = GraphSpec(
        act,
        pex[:na] + [_zeros(1, env.obs_dim), _zeros(1, env.act_dim)],
        [f"p:{nm}" for nm in p_names[:na]] + ["obs", "noise"],
        ["action"],
    )
    b.graphs["learn_critic"] = GraphSpec(
        learn_critic,
        pex + pex[na:] + _batch_examples(env, batch_size)
        + [_zeros(batch_size, env.act_dim)],
        [f"p:{nm}" for nm in p_names]
        + [f"t:{nm}" for nm in p_names[na:]]
        + BATCH_NAMES
        + ["noise"],
        [f"g:{nm}" for nm in p_names[na:]] + ["td_abs", "loss"],
        grad_slice=(na, n),
    )
    b.graphs["learn_actor"] = GraphSpec(
        learn_actor,
        pex + [_zeros(batch_size, env.obs_dim), _zeros(batch_size, env.act_dim)],
        [f"p:{nm}" for nm in p_names] + ["obs", "noise"],
        [f"g:{nm}" for nm in p_names[:na]] + ["td_abs", "loss"],
        grad_slice=(0, na),
    )
    return b


# --------------------------------------------------------------------------
# Registry.
# --------------------------------------------------------------------------

def build(algo: str, env: EnvSpec, hidden=(64, 64), batch_size=64, gamma=0.99, seed=0,
          **kw) -> AlgoBuild:
    if algo == "dqn":
        return build_dqn(env, hidden, batch_size, gamma, double=False, seed=seed, **kw)
    if algo == "ddqn":
        return build_dqn(env, hidden, batch_size, gamma, double=True, seed=seed, **kw)
    if algo == "ddpg":
        return build_ddpg(env, hidden, batch_size, gamma, seed=seed, **kw)
    if algo == "td3":
        return build_td3(env, hidden, batch_size, gamma, seed=seed, **kw)
    if algo == "sac":
        return build_sac(env, hidden, batch_size, gamma, seed=seed, **kw)
    raise ValueError(f"unknown algo {algo!r}")
