"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest checks kernel == ref for values AND gradients)."""

import jax.numpy as jnp


def fused_linear_ref(x, w, b, activation="none"):
    z = x @ w + b[None, :]
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "none":
        return z
    raise ValueError(activation)


def td_loss_ref(pred, target, weight, mode="huber", delta=1.0):
    td = pred - target
    td_abs = jnp.abs(td)
    if mode == "huber":
        quad = jnp.minimum(td_abs, delta)
        loss = 0.5 * quad * quad + delta * (td_abs - quad)
    elif mode == "mse":
        loss = td * td
    else:
        raise ValueError(mode)
    return weight * loss, td_abs


def mlp_ref(params, x, hidden_act="relu", out_act="none"):
    """params: [(w, b), ...]; reference MLP for model-level tests."""
    h = x
    for i, (w, b) in enumerate(params):
        act = out_act if i == len(params) - 1 else hidden_act
        h = fused_linear_ref(h, w, b, act)
    return h
