"""L1 Pallas kernel: fused linear layer  y = act(x @ W + b).

The MLP forward/backward is the compute hot-spot of every learner graph
(paper §V-B: learners run SGD on the collected data). On the paper's GPU
this is a cuBLAS GEMM + separate bias/activation kernels; the TPU-shaped
re-think (DESIGN.md §Hardware-Adaptation) fuses bias and activation into
the GEMM epilogue so activations never round-trip to HBM, and expresses
the HBM->VMEM schedule with BlockSpec tiles sized for VMEM residency
(everything here fits VMEM whole at our model sizes: B,dims <= 1024 f32
=> < 8 MiB, well under the ~16 MiB/core budget; the MXU sees (B, IN) x
(IN, OUT) contractions directly).

`pallas_call` has no automatic VJP, so the backward pass is ALSO a Pallas
kernel, wired up with `jax.custom_vjp`:

    gz = g * act'(y)            (elementwise, fused)
    dx = gz @ W^T               (MXU)
    dW = x^T @ gz               (MXU)
    db = sum_B gz               (VPU reduction)

Activation derivative is recomputed from `y` (relu': y>0; tanh': 1-y^2),
so the residual saved between fwd and bwd is just (x, W, y).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU these lower unchanged with interpret=False.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# CPU PJRT can only run interpret-mode Pallas; flip for real TPU builds.
INTERPRET = True

ACTIVATIONS = ("none", "relu", "tanh")


def _apply_act(z, activation):
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    return z


def _act_grad_from_y(y, activation):
    """act'(z) recomputed from y = act(z)."""
    if activation == "relu":
        return (y > 0.0).astype(y.dtype)
    if activation == "tanh":
        return 1.0 - y * y
    return jnp.ones_like(y)


def _fwd_kernel(x_ref, w_ref, b_ref, y_ref, *, activation):
    """y = act(x @ W + b). Whole-array block: one MXU contraction."""
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...][None, :]
    y_ref[...] = _apply_act(z, activation)


def _bwd_kernel(x_ref, w_ref, y_ref, g_ref, dx_ref, dw_ref, db_ref, *, activation):
    """Fused backward: gz = g * act'(y); dx, dW, db in one kernel."""
    gz = g_ref[...] * _act_grad_from_y(y_ref[...], activation)
    dx_ref[...] = jnp.dot(gz, w_ref[...].T, preferred_element_type=jnp.float32)
    dw_ref[...] = jnp.dot(x_ref[...].T, gz, preferred_element_type=jnp.float32)
    db_ref[...] = jnp.sum(gz, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="none"):
    """act(x @ W + b) as a Pallas kernel with a Pallas backward.

    Args:
      x: (B, IN) f32.
      w: (IN, OUT) f32.
      b: (OUT,) f32.
      activation: one of "none" | "relu" | "tanh" (static).
    Returns:
      (B, OUT) f32.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    batch, _ = x.shape
    out = w.shape[1]
    return pl.pallas_call(
        functools.partial(_fwd_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((batch, out), x.dtype),
        interpret=INTERPRET,
    )(x, w, b)


def _fused_linear_fwd(x, w, b, activation):
    y = fused_linear(x, w, b, activation)
    return y, (x, w, y)


def _fused_linear_bwd(activation, res, g):
    x, w, y = res
    dx, dw, db = pl.pallas_call(
        functools.partial(_bwd_kernel, activation=activation),
        out_shape=(
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct((w.shape[1],), w.dtype),
        ),
        interpret=INTERPRET,
    )(x, w, y, g)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def vmem_bytes(batch: int, in_dim: int, out_dim: int) -> int:
    """Estimated VMEM residency of the fused fwd kernel (f32)."""
    return 4 * (batch * in_dim + in_dim * out_dim + out_dim + batch * out_dim)
