"""L1 Pallas kernel: fused weighted TD loss.

Computes, in one pass over the batch (one HBM read per operand instead of
three separate elementwise kernels):

    td       = pred - target
    td_abs   = |td|                      (the replay-buffer priority feed)
    loss_vec = w * huber_delta(td)       (or w * td^2 in "mse" mode)

This is the learner-side half of the paper's Algorithm 1 lines 15-18: the
importance weights multiply the TD objective, and |TD| flows back into
`update_priority`. Backward is analytic and fused the same way:

    d loss_vec / d pred = w * clamp(td, -delta, delta)    (huber)
                          w * 2 * td                      (mse)

so the VJP is a single elementwise Pallas kernel as well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import INTERPRET

MODES = ("huber", "mse")


def _fwd_kernel(pred_ref, target_ref, w_ref, loss_ref, tdabs_ref, *, mode, delta):
    td = pred_ref[...] - target_ref[...]
    tdabs_ref[...] = jnp.abs(td)
    if mode == "huber":
        a = jnp.abs(td)
        quad = jnp.minimum(a, delta)
        loss = 0.5 * quad * quad + delta * (a - quad)
    else:
        loss = td * td
    loss_ref[...] = w_ref[...] * loss


def _bwd_kernel(pred_ref, target_ref, w_ref, g_ref, dpred_ref, *, mode, delta):
    td = pred_ref[...] - target_ref[...]
    if mode == "huber":
        grad = jnp.clip(td, -delta, delta)
    else:
        grad = 2.0 * td
    dpred_ref[...] = g_ref[...] * w_ref[...] * grad


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def td_loss(pred, target, weight, mode="huber", delta=1.0):
    """Weighted TD loss vector and |TD| priorities.

    Args:
      pred: (B,) f32 — Q(s, a) under the online network.
      target: (B,) f32 — bootstrapped target (stop-gradient side).
      weight: (B,) f32 — importance weights is(i).
      mode: "huber" | "mse" (static).
      delta: huber threshold (static).
    Returns:
      (loss_vec, td_abs): each (B,) f32. Gradients flow to `pred` only.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}")
    return pl.pallas_call(
        functools.partial(_fwd_kernel, mode=mode, delta=delta),
        out_shape=(
            jax.ShapeDtypeStruct(pred.shape, pred.dtype),
            jax.ShapeDtypeStruct(pred.shape, pred.dtype),
        ),
        interpret=INTERPRET,
    )(pred, target, weight)


def _td_loss_fwd(pred, target, weight, mode, delta):
    out = td_loss(pred, target, weight, mode, delta)
    return out, (pred, target, weight)


def _td_loss_bwd(mode, delta, res, g):
    pred, target, weight = res
    g_loss, _g_tdabs = g  # |TD| output is a priority feed, not a loss term
    dpred = pl.pallas_call(
        functools.partial(_bwd_kernel, mode=mode, delta=delta),
        out_shape=jax.ShapeDtypeStruct(pred.shape, pred.dtype),
        interpret=INTERPRET,
    )(pred, target, weight, g_loss)
    return dpred, None, None


td_loss.defvjp(_td_loss_fwd, _td_loss_bwd)
