"""L1 correctness: Pallas kernels vs pure-jnp oracles, values AND grads.

Hypothesis sweeps shapes; fixed-seed numpy draws the values. This is the
core build-time correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import ACTIVATIONS, fused_linear, vmem_bytes
from compile.kernels.td_error import MODES, td_loss

DIMS = st.integers(min_value=1, max_value=48)


def rnd(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------- forward


@settings(max_examples=30, deadline=None)
@given(batch=DIMS, in_dim=DIMS, out_dim=DIMS, act=st.sampled_from(ACTIVATIONS),
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(batch, in_dim, out_dim, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, batch, in_dim), rnd(rng, in_dim, out_dim), rnd(rng, out_dim)
    got = fused_linear(x, w, b, act)
    want = ref.fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(batch=DIMS, mode=st.sampled_from(MODES),
       delta=st.floats(0.1, 5.0), seed=st.integers(0, 2**31 - 1))
def test_td_loss_matches_ref(batch, mode, delta, seed):
    rng = np.random.default_rng(seed)
    pred, target = rnd(rng, batch), rnd(rng, batch)
    weight = jnp.abs(rnd(rng, batch)) + 0.01
    got_loss, got_td = td_loss(pred, target, weight, mode, delta)
    want_loss, want_td = ref.td_loss_ref(pred, target, weight, mode, delta)
    np.testing.assert_allclose(got_loss, want_loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_td, want_td, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- backward


@settings(max_examples=15, deadline=None)
@given(batch=DIMS, in_dim=DIMS, out_dim=DIMS, act=st.sampled_from(ACTIVATIONS),
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_grads_match_ref(batch, in_dim, out_dim, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, batch, in_dim), rnd(rng, in_dim, out_dim), rnd(rng, out_dim)

    def f_kernel(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, act)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.fused_linear_ref(x, w, b, act)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=2e-4, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(batch=DIMS, mode=st.sampled_from(MODES), seed=st.integers(0, 2**31 - 1))
def test_td_loss_grads_match_ref(batch, mode, seed):
    rng = np.random.default_rng(seed)
    pred, target = rnd(rng, batch), rnd(rng, batch)
    weight = jnp.abs(rnd(rng, batch)) + 0.01

    def f_kernel(p):
        loss, _ = td_loss(p, target, weight, mode, 1.0)
        return jnp.mean(loss)

    def f_ref(p):
        loss, _ = ref.td_loss_ref(p, target, weight, mode, 1.0)
        return jnp.mean(loss)

    gk = jax.grad(f_kernel)(pred)
    gr = jax.grad(f_ref)(pred)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-6)


def test_td_loss_target_and_weight_get_no_grad():
    pred = jnp.ones(4)
    target = jnp.zeros(4)
    weight = jnp.ones(4)

    def f(t, w):
        loss, _ = td_loss(pred, t, w, "mse", 1.0)
        return jnp.sum(loss)

    gt, gw = jax.grad(f, argnums=(0, 1))(target, weight)
    # custom_vjp returns None -> zeros for non-diff inputs.
    np.testing.assert_allclose(gt, np.zeros(4))
    np.testing.assert_allclose(gw, np.zeros(4))


# ------------------------------------------------------------- edge cases


def test_unknown_activation_raises():
    x = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        fused_linear(x, x, jnp.zeros(2), "gelu")


def test_unknown_mode_raises():
    v = jnp.zeros(3)
    with pytest.raises(ValueError):
        td_loss(v, v, v, "mae", 1.0)


def test_huber_transitions_at_delta():
    pred = jnp.array([0.5, 2.0])
    target = jnp.zeros(2)
    w = jnp.ones(2)
    loss, _ = td_loss(pred, target, w, "huber", 1.0)
    np.testing.assert_allclose(loss[0], 0.125, rtol=1e-6)  # quadratic zone
    np.testing.assert_allclose(loss[1], 1.5, rtol=1e-6)    # linear zone

def test_vmem_estimate_within_budget():
    # Largest graph config we ship must fit VMEM comfortably.
    assert vmem_bytes(batch=256, in_dim=256, out_dim=256) < 4 * 2**20
