"""L2 correctness: algorithm graphs — shapes, gradient plumbing, learning
sanity (loss decreases under plain GD on a fixed batch)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.envs import ENVS
from compile.model import (
    ALGOS,
    _sac_actor_sample,
    build,
    mlp_apply,
    mlp_init,
)
from compile.kernels import ref

CONFIGS = [
    ("dqn", "CartPole-v1"),
    ("ddqn", "CartPole-v1"),
    ("ddpg", "Pendulum-v1"),
    ("td3", "Pendulum-v1"),
    ("sac", "Pendulum-v1"),
]
BATCH = 16
HIDDEN = (32, 32)


def make(algo, env_name, **kw):
    return build(algo, ENVS[env_name], hidden=HIDDEN, batch_size=BATCH, seed=3, **kw)


def run_graph(b, name):
    spec = b.graphs[name]
    out = jax.jit(spec.fn)(*spec.example_args)
    assert len(out) == len(spec.output_names), name
    return spec, out


def fake_batch(rng, env, batch):
    obs = rng.standard_normal((batch, env.obs_dim), dtype=np.float32)
    next_obs = rng.standard_normal((batch, env.obs_dim), dtype=np.float32)
    if env.discrete:
        action = rng.integers(0, env.n_actions, (batch, 1)).astype(np.float32)
    else:
        action = rng.uniform(-env.act_high, env.act_high,
                             (batch, env.act_dim)).astype(np.float32)
    reward = rng.standard_normal(batch).astype(np.float32)
    done = (rng.random(batch) < 0.1).astype(np.float32)
    isw = np.ones(batch, np.float32)
    return [obs, action, next_obs, reward, done, isw]


# ------------------------------------------------------------- structure


@pytest.mark.parametrize("algo,env_name", CONFIGS)
def test_act_graph_shapes(algo, env_name):
    b = make(algo, env_name)
    spec, out = run_graph(b, "act")
    action = out[0]
    env = ENVS[env_name]
    if env.discrete:
        assert action.shape == (1,)
        assert float(action[0]) in range(env.n_actions)
    else:
        assert action.shape == (1, env.act_dim)
        assert np.all(np.abs(np.asarray(action)) <= env.act_high + 1e-5)


@pytest.mark.parametrize("algo,env_name", CONFIGS)
def test_learn_graphs_shapes_and_grad_alignment(algo, env_name):
    b = make(algo, env_name)
    for gname, spec in b.graphs.items():
        if not gname.startswith("learn"):
            continue
        out = jax.jit(spec.fn)(*spec.example_args)
        lo, hi = spec.grad_slice
        grads, td_abs, loss = out[: hi - lo], out[-2], out[-1]
        assert len(grads) == hi - lo, gname
        for g, p in zip(grads, b.init_params[lo:hi]):
            assert g.shape == p.shape, f"{gname}: grad/param shape mismatch"
        assert td_abs.shape == (BATCH,)
        assert np.isfinite(float(loss))


@pytest.mark.parametrize("algo,env_name", CONFIGS)
def test_params_deterministic_across_builds(algo, env_name):
    a = make(algo, env_name)
    b = make(algo, env_name)
    for p, q in zip(a.init_params, b.init_params):
        np.testing.assert_array_equal(p, q)


def test_mlp_apply_matches_ref():
    rng = np.random.default_rng(0)
    flat = mlp_init(rng, [5, 16, 3])
    params_pairs = [(flat[0], flat[1]), (flat[2], flat[3])]
    x = jnp.asarray(rng.standard_normal((7, 5), dtype=np.float32))
    got = mlp_apply(list(map(jnp.asarray, flat)), x)
    want = ref.mlp_ref(params_pairs, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- learning


def gd_step(params, grads, lr):
    return [p - lr * g for p, g in zip(params, grads)]


@pytest.mark.parametrize("algo,env_name", [("dqn", "CartPole-v1"),
                                           ("ddqn", "CartPole-v1")])
def test_dqn_loss_decreases_under_gd(algo, env_name):
    b = make(algo, env_name)
    env = ENVS[env_name]
    rng = np.random.default_rng(7)
    batch = fake_batch(rng, env, BATCH)
    spec = b.graphs["learn"]
    n = len(b.init_params)
    learn = jax.jit(spec.fn)
    params = [jnp.asarray(p) for p in b.init_params]
    tparams = [jnp.asarray(p) for p in b.init_params]
    losses = []
    for _ in range(200):
        out = learn(*params, *tparams, *batch)
        grads, loss = out[:n], float(out[-1])
        losses.append(loss)
        params = gd_step(params, grads, 0.2)
    assert losses[-1] < 0.2 * losses[0], losses[::50]


def test_ddpg_critic_loss_decreases_under_gd():
    b = make("ddpg", "Pendulum-v1")
    env = ENVS["Pendulum-v1"]
    rng = np.random.default_rng(8)
    batch = fake_batch(rng, env, BATCH)
    spec = b.graphs["learn"]
    n = len(b.init_params)
    learn = jax.jit(spec.fn)
    params = [jnp.asarray(p) for p in b.init_params]
    tparams = [jnp.asarray(p) for p in b.init_params]
    na = n - len(mlp_init(np.random.default_rng(0),
                          [env.obs_dim + env.act_dim, *HIDDEN, 1]))
    td0 = td_last = None
    for i in range(200):
        out = learn(*params, *tparams, *batch)
        grads = out[:n]
        td = float(jnp.mean(out[-2]))
        if i == 0:
            td0 = td
        td_last = td
        # Update critic only (actor loss fights the critic objective).
        params = params[:na] + gd_step(params[na:], grads[na:], 0.05)
    assert td_last < 0.5 * td0, (td0, td_last)


def assemble_inputs(spec, b, params, tparams, batch, noise):
    """Build the precise positional argument list from declared names
    (mirrors the rust agent's by-name assembly)."""
    by_name = dict(zip(b.param_names, params))
    t_by_name = dict(zip(b.param_names, tparams))
    roles = dict(zip(["obs", "action", "next_obs", "reward", "done", "is_weights"], batch))
    args = []
    for nm in spec.input_names:
        if nm.startswith("p:"):
            args.append(by_name[nm[2:]])
        elif nm.startswith("t:"):
            args.append(t_by_name[nm[2:]])
        elif nm == "noise":
            args.append(noise)
        else:
            args.append(roles[nm])
    return args


@pytest.mark.parametrize("algo", ["td3", "sac"])
def test_twin_critic_loss_decreases(algo):
    b = make(algo, "Pendulum-v1")
    env = ENVS["Pendulum-v1"]
    rng = np.random.default_rng(9)
    batch = fake_batch(rng, env, BATCH)
    noise = rng.standard_normal((BATCH, env.act_dim), dtype=np.float32)
    spec = b.graphs["learn_critic"]
    lo, hi = spec.grad_slice
    learn = jax.jit(spec.fn)
    params = [jnp.asarray(p) for p in b.init_params]
    tparams = [jnp.asarray(p) for p in b.init_params]
    first = last = None
    for i in range(200):
        out = learn(*assemble_inputs(spec, b, params, tparams, batch, noise))
        grads, loss = out[: hi - lo], float(out[-1])
        if i == 0:
            first = loss
        last = loss
        params = params[:lo] + gd_step(params[lo:hi], grads, 0.05) + params[hi:]
    assert last < 0.5 * first, (first, last)


def test_actor_graphs_produce_nonzero_grads():
    for algo in ["td3", "sac"]:
        b = make(algo, "Pendulum-v1")
        spec = b.graphs["learn_actor"]
        rng = np.random.default_rng(11)
        args = []
        for a, nm in zip(spec.example_args, spec.input_names):
            if nm.startswith(("p:", "t:")):
                args.append(jnp.asarray(rng.standard_normal(a.shape,
                                                            dtype=np.float32) * 0.1))
            else:
                args.append(jnp.asarray(rng.standard_normal(a.shape,
                                                            dtype=np.float32)))
        out = jax.jit(spec.fn)(*args)
        lo, hi = spec.grad_slice
        total = sum(float(jnp.sum(jnp.abs(g))) for g in out[: hi - lo])
        assert total > 0.0, algo


# ------------------------------------------------------------- SAC math


def test_sac_sample_logprob_matches_manual():
    env = ENVS["Pendulum-v1"]
    rng = np.random.default_rng(5)
    actor = [jnp.asarray(p) for p in
             mlp_init(rng, [env.obs_dim, 32, 32, 2 * env.act_dim])]
    obs = jnp.asarray(rng.standard_normal((6, env.obs_dim), dtype=np.float32))
    noise = jnp.asarray(rng.standard_normal((6, env.act_dim), dtype=np.float32))
    a, logp = _sac_actor_sample(actor, obs, noise, env.act_high)
    assert a.shape == (6, env.act_dim)
    assert np.all(np.abs(np.asarray(a)) <= env.act_high + 1e-5)

    # Manual recomputation.
    out = np.asarray(mlp_apply(actor, obs))
    mean, log_std = np.split(out, 2, axis=-1)
    log_std = np.clip(log_std, -20.0, 2.0)
    std = np.exp(log_std)
    pre = mean + std * np.asarray(noise)
    gauss = -0.5 * (((pre - mean) / std) ** 2 + 2 * log_std + np.log(2 * np.pi))
    corr = 2.0 * (np.log(2.0) - pre - np.logaddexp(0.0, -2.0 * pre))
    want = gauss.sum(-1) - corr.sum(-1)
    np.testing.assert_allclose(logp, want, rtol=1e-4, atol=1e-4)


def test_build_rejects_mismatched_spaces():
    with pytest.raises(AssertionError):
        build("dqn", ENVS["Pendulum-v1"])
    with pytest.raises(AssertionError):
        build("sac", ENVS["CartPole-v1"])
    with pytest.raises(ValueError):
        build("ppo", ENVS["CartPole-v1"])


def test_all_algos_buildable_on_defaults():
    for algo in ALGOS:
        env = ENVS["CartPole-v1"] if algo in ("dqn", "ddqn") else ENVS["Pendulum-v1"]
        b = build(algo, env, hidden=(16,), batch_size=4)
        assert "act" in b.graphs
        assert any(g.startswith("learn") for g in b.graphs)
